"""Content-addressed chunk store: digests, stores, chunk-level delta."""
import numpy as np
import pytest

from repro.core import ExecutionState, StateReducer
from repro.core.chunkstore import (
    DiskChunkStore, MemoryChunkStore, array_chunk_digests, decode_chunk,
    digest_bytes, effective_chunk_bytes, encode_chunk, split_chunks,
)

CHUNK = 64 << 10


# ----------------------------------------------------------------------
# chunking + digests
# ----------------------------------------------------------------------

def test_split_and_digests_align():
    rng = np.random.default_rng(0)
    for n in (0, 1, 1000, CHUNK, CHUNK + 1, 3 * CHUNK + 777):
        raw = rng.integers(0, 255, n, np.uint8).tobytes()
        chunks = split_chunks(raw, CHUNK)
        digs = array_chunk_digests(raw, CHUNK)
        assert len(chunks) == len(digs)
        assert b"".join(chunks) == raw


def test_chunk_digest_locality():
    """Mutating one element changes only the digest of its chunk."""
    x = np.arange(1 << 18, dtype=np.float32)        # 1 MiB
    d0 = array_chunk_digests(x.tobytes(), CHUNK)
    x[5] += 1.0                                     # inside chunk 0
    d1 = array_chunk_digests(x.tobytes(), CHUNK)
    assert d0[0] != d1[0]
    assert d0[1:] == d1[1:]


def test_chunk_digests_are_64bit_and_length_salted():
    digs = array_chunk_digests(np.arange(4096, dtype=np.float32).tobytes())
    assert any(d > 2**32 for d in digs)
    # zero payloads of different lengths must not alias (padding salt)
    a = array_chunk_digests(bytes(1000))
    b = array_chunk_digests(bytes(1024))
    assert a != b


def test_effective_chunk_bytes_rules():
    assert effective_chunk_bytes(100, 0) == 100          # whole-payload mode
    assert effective_chunk_bytes(100, 1 << 20) == 100    # fits in one chunk
    eff = effective_chunk_bytes(10 << 20, 100_000)
    assert eff % 1024 == 0 and eff <= 100_000            # block-aligned


def test_encode_decode_chunk_roundtrip_all_codecs():
    raw = np.arange(5000, dtype=np.int32).tobytes()
    for codec in ("none", "zlib", "zstd"):
        assert decode_chunk(encode_chunk(raw, codec)) == raw


# ----------------------------------------------------------------------
# stores
# ----------------------------------------------------------------------

def test_memory_store_dedups():
    st = MemoryChunkStore()
    d = digest_bytes(b"hello")
    st.put(d, b"payload")
    st.put(d, b"other")            # content-addressed: first write wins
    assert st.get(d) == b"payload"
    assert st.has(d) and len(st) == 1


def test_memory_store_evicts_least_recent_past_budget():
    st = MemoryChunkStore(max_bytes=300)
    d1, d2, d3 = digest_bytes(b"1"), digest_bytes(b"2"), digest_bytes(b"3")
    st.put(d1, b"a" * 120)
    st.put(d2, b"b" * 120)
    assert st.has(d1)                   # touch: d1 is now most recent
    st.put(d3, b"c" * 120)              # over budget: evicts d2, not d1
    assert not st.has(d2)
    assert st.has(d1) and st.has(d3)
    assert st.nbytes <= 300


def test_disk_store_roundtrip_and_persistence(tmp_path):
    st = DiskChunkStore(str(tmp_path))
    d = digest_bytes(b"abc")
    st.put(d, b"chunk-bytes")
    # a fresh store over the same directory sees the chunk
    st2 = DiskChunkStore(str(tmp_path))
    assert st2.has(d)
    assert st2.get(d) == b"chunk-bytes"
    assert st2.digests() == {d}
    st2.remove(d)
    assert not st2.has(d)


def test_disk_store_detects_corruption(tmp_path):
    import os
    st = DiskChunkStore(str(tmp_path))
    d = digest_bytes(b"abc")
    st.put(d, b"x" * 100)
    fn = [f for f in os.listdir(tmp_path) if f.endswith(".bin")][0]
    p = tmp_path / fn
    data = bytearray(p.read_bytes())
    data[10] ^= 0xFF
    p.write_bytes(bytes(data))
    with pytest.raises(IOError):
        st.get(d)


# ----------------------------------------------------------------------
# reducer integration: chunk dedup within one capture
# ----------------------------------------------------------------------

def test_serialize_dedups_identical_chunks():
    red = StateReducer("none", chunk_bytes=CHUNK)
    big_zeros = np.zeros(1 << 18, np.float32)       # 16 identical chunks
    ser = red.serialize_names(ExecutionState({"z": big_zeros}), ["z"])
    assert len(ser.chunks) == 1                     # one unique chunk stored
    assert ser.nbytes < big_zeros.nbytes / 4
    out = red.deserialize(ser)
    np.testing.assert_array_equal(out["z"], big_zeros)


def test_wire_nbytes_counts_only_missing_chunks():
    red = StateReducer("none", chunk_bytes=CHUNK)
    x = np.arange(1 << 17, dtype=np.float32)
    ser = red.serialize_names(ExecutionState({"x": x}), ["x"])
    full = ser.wire_nbytes(set())
    none = ser.wire_nbytes(set(ser.chunks))
    assert full > x.nbytes                          # payload + manifest
    assert none < full / 10                         # manifest + pickle only


# ----------------------------------------------------------------------
# batched chunk digesting (one launch for a whole manifest of payloads)
# ----------------------------------------------------------------------

def test_batched_chunk_digests_match_per_payload_bit_for_bit():
    from repro.core.chunkstore import array_chunk_digests_many
    rng = np.random.default_rng(2)
    payloads = [rng.integers(0, 256, n, dtype=np.uint8).tobytes()
                for n in (0, 1, 1023, 1024, 5000, 3 * 4096 + 17)]
    per = [array_chunk_digests(p, 4096) for p in payloads]
    many, h64s = array_chunk_digests_many(payloads, 4096)
    assert many == per
    assert [len(h) for h in h64s] == [(len(p) + 1023) // 1024
                                      for p in payloads]


def test_batched_chunk_digests_all_empty_payloads():
    from repro.core.chunkstore import array_chunk_digests_many
    many, h64s = array_chunk_digests_many([b"", b""])
    assert many == [[], []]
    assert all(len(h) == 0 for h in h64s)
    assert array_chunk_digests_many([]) == ([], [])


def test_batched_chunk_digest_prior_reuse_is_content_verified():
    from repro.core.chunkstore import array_chunk_digests_many
    rng = np.random.default_rng(4)
    payloads = [rng.integers(0, 256, 5 * 4096, dtype=np.uint8).tobytes()
                for _ in range(4)]
    digs, h64s = array_chunk_digests_many(payloads, 4096)
    priors = [(h, d, len(p)) for h, d, p in zip(h64s, digs, payloads)]

    # mutate one payload, shrink another: both must be freshly digested,
    # the untouched ones may reuse — results identical either way
    mutated = list(payloads)
    mutated[1] = b"\xff" + mutated[1][1:]
    mutated[2] = mutated[2][: 3 * 4096]
    again, _ = array_chunk_digests_many(mutated, 4096, priors=priors)
    fresh = [array_chunk_digests(p, 4096) for p in mutated]
    assert again == fresh

    # a stale cache entry (prior from an older payload version) is caught
    # by the on-device block compare, never served
    stale = [priors[1]] + [None] * 3          # wrong prior for payload 0
    out, _ = array_chunk_digests_many(mutated, 4096, priors=stale)
    assert out == fresh
