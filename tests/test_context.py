"""Context detector (paper §II-B, Algorithm 1)."""
from _hyp_compat import given, settings, st

from repro.core import ContextDetector, get_sequences, sequence_stats


def test_paper_example_sequences():
    # §II-B: "1,2,3,2,3 contains two sequences: 1,2,3 and 2,3"
    assert get_sequences([1, 2, 3, 2, 3]) == [(1, 2, 3), (2, 3)]


def test_paper_example_scores():
    stats = sequence_stats([1, 2, 3, 2, 3])
    assert abs(stats[(2, 3)] - 200 / 3) < 1e-9      # subset of (1,2,3) -> 2/3
    assert abs(stats[(1, 2, 3)] - 100 / 3) < 1e-9


def test_duplicates_counted():
    # two identical (2,3) runs + one (1,2,3): (2,3) subtotal = 2 + 1
    stats = sequence_stats([2, 3, 2, 3, 1, 2, 3])
    assert stats[(2, 3)] > stats[(1, 2, 3)]


def test_current_cell_filter():
    stats = sequence_stats([1, 2, 3, 5, 6, 5, 6], current_order=5)
    assert all(5 in s for s in stats)


@given(st.lists(st.integers(0, 9), min_size=1, max_size=60))
@settings(max_examples=200, deadline=None)
def test_sequences_partition_history(hist):
    seqs = get_sequences(hist)
    # invariant 1: concatenation reproduces the history
    flat = [o for s in seqs for o in s]
    assert flat == hist
    # invariant 2: every run is non-decreasing
    for s in seqs:
        assert all(a <= b for a, b in zip(s, s[1:]))


@given(st.lists(st.integers(0, 6), min_size=2, max_size=50))
@settings(max_examples=200, deadline=None)
def test_scores_normalized(hist):
    stats = sequence_stats(hist)
    assert abs(sum(stats.values()) - 100.0) < 1e-6
    assert all(v > 0 for v in stats.values())


def test_predict_block_from_history():
    det = ContextDetector()
    for _ in range(3):
        for o in (2, 3, 4):
            det.record("nb", o)
    det.record("nb", 0)
    assert det.predict_block("nb", 2) == (2, 3, 4)
    assert det.predict_block("nb", 3) == (3, 4)
    # unseen cell: degenerate block of itself
    assert det.predict_block("nb", 9) == (9,)


def test_detector_consumes_telemetry():
    from repro.core import telemetry as T
    bus = T.MQBus()
    det = ContextDetector()
    det.attach(bus)
    ids = ("a", "b", "c")
    for cid, order in (("a", 0), ("b", 1), ("c", 2)):
        bus.publish("telemetry", T.TelemetryMessage(
            datetime=0.0, type=T.CELL_EXECUTION_COMPLETED, cell_id=cid,
            notebook="nb", cell_ids=ids, session="s", path="p",
            payload={"order": order}))
    assert det.history["nb"] == [0, 1, 2]


def test_detector_drops_event_for_deleted_cell():
    """A completion event whose cell was deleted/renamed mid-session (and
    has no explicit order) must be dropped, not crash the bus dispatch."""
    from repro.core import telemetry as T
    bus = T.MQBus()
    det = ContextDetector()
    det.attach(bus)
    bus.publish("telemetry", T.TelemetryMessage(
        datetime=0.0, type=T.CELL_EXECUTION_COMPLETED, cell_id="gone",
        notebook="nb", cell_ids=("a", "b"), session="s", path="p"))
    assert det.history["nb"] == []          # dropped gracefully
    # a well-formed event afterwards still lands
    bus.publish("telemetry", T.TelemetryMessage(
        datetime=0.0, type=T.CELL_EXECUTION_COMPLETED, cell_id="a",
        notebook="nb", cell_ids=("a", "b"), session="s", path="p"))
    assert det.history["nb"] == [0]


def test_bus_unsubscribe_and_detach():
    from repro.core import telemetry as T
    bus = T.MQBus()
    det = ContextDetector()
    det.attach(bus)
    assert bus.subscriber_count("telemetry") == 1
    det.detach()
    assert bus.subscriber_count("telemetry") == 0
    bus.publish("telemetry", T.TelemetryMessage(
        datetime=0.0, type=T.CELL_EXECUTION_COMPLETED, cell_id="a",
        notebook="nb", cell_ids=("a",), session="s", path="p",
        payload={"order": 0}))
    assert det.history["nb"] == []          # detached: no delivery
    assert det.detach() is None             # idempotent
    assert bus.unsubscribe("telemetry", det.on_message) is False


def test_bus_history_ring_buffer():
    from repro.core import telemetry as T
    bus = T.MQBus(history_limit=3)
    for i in range(10):
        bus.publish("telemetry", T.TelemetryMessage(
            datetime=float(i), type=T.CELL_EXECUTION_COMPLETED, cell_id="a",
            notebook="nb", cell_ids=("a",), session="s", path="p",
            payload={"order": i}))
    msgs = bus.messages()
    assert len(msgs) == 3                   # bounded, not the full 10
    assert [m.payload["order"] for m in msgs] == [7, 8, 9]


def test_detector_with_pluggable_model():
    det = ContextDetector("markov")
    for _ in range(4):
        for o in (0, 1, 2):
            det.record("nb", o)
    assert det.model.name == "markov"
    dist = det.distribution("nb", 1)
    assert abs(sum(dist.values()) - 1.0) < 1e-9
    assert det.predict_next("nb", 1) == 2
    assert det.history["nb"][:3] == [0, 1, 2]   # history still recorded
    # Algorithm-1 stats stay served (reference rescan for non-freq models)
    assert det.stats("nb")
