"""Cost plane: priced envs, spot hazards, egress-priced links, and the
price-aware horizon DP — including the degenerate-case guarantees (zero
prices must reproduce the seconds-only DP and the committed decision
goldens bit-for-bit)."""
import json
import os

import pytest

from repro.core import (
    EnvironmentRegistry, ExecutionEnvironment, HybridRuntime,
    MigrationAnalyzer, SessionScheduler, gpu_training_notebook,
    remote_sensing_notebook,
)
from repro.launch.notebook import (
    parse_egress_spec, parse_hazard_spec, parse_price_spec,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "fig_decisions_golden.json")


# -- fabric: prices, hazards, egress -----------------------------------


def test_env_price_and_hazard_tags():
    e = ExecutionEnvironment("spot", speedup=8.0, price_per_hour=0.9,
                             hazard_rate=1 / 600)
    assert e.price_per_hour == 0.9 and e.spot
    assert not ExecutionEnvironment("ondemand", price_per_hour=3.0).spot
    with pytest.raises(ValueError):
        ExecutionEnvironment("bad", price_per_hour=-1.0)
    with pytest.raises(ValueError):
        ExecutionEnvironment("bad", hazard_rate=-1.0)


def _reg(**envs):
    reg = EnvironmentRegistry(default_bandwidth=1e9, default_latency=0.1)
    reg.register(ExecutionEnvironment("local"), home=True)
    for name, kw in envs.items():
        reg.register(ExecutionEnvironment(name, **kw))
    return reg


def test_egress_pricing_is_directional():
    reg = _reg(remote={"speedup": 10.0})
    reg.set_egress("remote", "local", 90.0)
    assert reg.transfer_dollars("remote", "local", 2e9) == 180.0
    assert reg.transfer_dollars("local", "remote", 2e9) == 0.0
    assert reg.transfer_dollars("local", "local", 2e9) == 0.0


def test_asymmetric_link_via_connect_reverse_overrides():
    reg = _reg(remote={"speedup": 10.0})
    reg.connect("local", "remote", bandwidth=1e9, latency=0.1,
                egress_per_gb=0.0, reverse_bandwidth=2e8,
                reverse_egress_per_gb=0.09)
    fwd, back = reg.link("local", "remote"), reg.link("remote", "local")
    assert fwd.bandwidth == 1e9 and back.bandwidth == 2e8
    assert fwd.egress_per_gb == 0.0 and back.egress_per_gb == 0.09
    assert back.latency == fwd.latency          # falls back to forward


def test_clone_topology_carries_prices_and_hazards():
    reg = _reg(spot={"speedup": 8.0, "price_per_hour": 0.9,
                     "hazard_rate": 1 / 300})
    clone = reg.clone_topology()
    assert clone["spot"].price_per_hour == 0.9
    assert clone["spot"].hazard_rate == 1 / 300


# -- analyzer: dollar helpers and the SLO ------------------------------


def _analyzer(**kw):
    from repro.core import ContextDetector, KnowledgeBase
    reg = _reg(ondemand={"speedup": 10.0, "price_per_hour": 3.6},
               spot={"speedup": 8.0, "price_per_hour": 0.9,
                     "hazard_rate": 1 / 100})
    an = MigrationAnalyzer(KnowledgeBase(), ContextDetector(),
                           registry=reg, **kw)
    return an, reg


def test_exec_dollars_and_transfer_dollars():
    an, reg = _analyzer(objective="dollars")
    assert an.exec_dollars(3600.0, "ondemand") == pytest.approx(3.6)
    assert an.exec_dollars(3600.0, "local") == 0.0
    reg.set_egress("spot", "local", 10.0)
    assert an.transfer_dollars(1e9, "spot", "local") == pytest.approx(10.0)


def test_hazard_surcharge_scales_with_exposure():
    an, _ = _analyzer(objective="dollars")
    s1, d1 = an.hazard_surcharge("spot", 10.0, 1 << 20)
    s2, d2 = an.hazard_surcharge("spot", 20.0, 1 << 20)
    assert s2 > s1 > 0.0 and d2 >= d1 >= 0.0
    assert an.hazard_surcharge("ondemand", 20.0, 1 << 20) == (0.0, 0.0)


def test_objective_validation():
    from repro.core import ContextDetector, KnowledgeBase
    with pytest.raises(ValueError):
        MigrationAnalyzer(KnowledgeBase(), ContextDetector(),
                          objective="euros")
    with pytest.raises(ValueError):
        MigrationAnalyzer(KnowledgeBase(), ContextDetector(),
                          objective="dollars")      # needs a registry
    with pytest.raises(ValueError):
        _analyzer(objective="dollars", slo=-1.0)


def _run_gpu(objective, slo, *, prices=True):
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.3)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment(
        "ondemand", speedup=10.0,
        price_per_hour=3.0 if prices else 0.0), capacity=4)
    reg.register(ExecutionEnvironment(
        "cheap", speedup=8.0,
        price_per_hour=0.9 if prices else 0.0), capacity=4)
    sched = SessionScheduler(reg)
    rt = sched.add_notebook(gpu_training_notebook(f"t-{objective}"),
                            policy="horizon", use_knowledge=False,
                            objective=objective, slo=slo)
    rep = sched.run()
    return rt, rep


def test_slo_forces_training_off_home_and_dollars_picks_cheap():
    # 45 s steps breach a 30 s SLO at home; the dollars DP must leave and
    # must prefer the $0.9/h env over the $3/h one
    rt, rep = _run_gpu("dollars", 30.0)
    assert rt.exec_env_seconds.get("cheap", 0.0) > 0.0
    assert rt.exec_env_seconds.get("ondemand", 0.0) == 0.0
    assert rep.slo_attainment == 1.0
    assert rep.total_dollars > 0.0
    # seconds DP on the same fleet chases the fastest env instead
    rt2, rep2 = _run_gpu("seconds", 30.0)
    assert rt2.exec_env_seconds.get("ondemand", 0.0) > 0.0
    assert rep2.total_dollars > rep.total_dollars


def test_without_slo_dollars_dp_stays_on_free_home():
    rt, rep = _run_gpu("dollars", None)
    assert rep.total_dollars == 0.0
    assert set(e for e, s in rt.exec_env_seconds.items() if s > 0) \
        == {"local"}


# -- degenerate case: zero prices == seconds DP ------------------------


def test_zero_price_fleet_matches_seconds_dp_schedule():
    rt_d, rep_d = _run_gpu("dollars", None, prices=False)
    rt_s, rep_s = _run_gpu("seconds", None, prices=False)
    assert rep_d.makespan == rep_s.makespan
    assert rep_d.actual_env_seconds == rep_s.actual_env_seconds
    assert rt_d.exec_env_seconds == rt_s.exec_env_seconds
    assert rep_d.total_dollars == rep_s.total_dollars == 0.0


def test_fig_decisions_bit_identical_with_cost_plane_in_tree():
    """Zero prices, no hazards, symmetric links: the fig5/fig11 decision
    sweeps must still reproduce the committed goldens bit-identically —
    the cost plane must not perturb a single seconds-DP decision."""
    from benchmarks import fig5_fig6_policy_speedups, fig11_knowledge_policy
    with open(GOLDEN) as f:
        golden = json.load(f)
    fresh5 = [[n, v, d]
              for n, v, d in fig5_fig6_policy_speedups.run(smoke=True)]
    fresh11 = [[n, v, d]
               for n, v, d in fig11_knowledge_policy.run(smoke=True)]
    assert fresh5 == golden["fig5_fig6"]
    assert fresh11 == golden["fig11"]


# -- spot hazards: seeded, deterministic, recoverable ------------------


def _spot_fleet(seed):
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.3)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment(
        "spot", speedup=8.0, price_per_hour=0.9,
        hazard_rate=1 / 30), capacity=4)
    sched = SessionScheduler(reg)
    sched.enable_recovery("checkpoint", interval=15.0)
    for i in range(2):
        sched.add_notebook(gpu_training_notebook(f"s{i}"),
                           policy="horizon", use_knowledge=False,
                           objective="dollars", slo=30.0)
    injected = sched.enable_spot_hazards(seed=seed, recover_after=10.0)
    return sched, injected


def test_spot_hazards_inject_through_failure_machinery():
    sched, injected = _spot_fleet(seed=2)
    assert injected > 0
    rep = sched.run()
    assert rep.preemptions == injected
    assert rep.recoveries > 0          # a preemption landed mid-run
    assert rep.total_dollars > 0.0


def test_seeded_spot_run_is_deterministic():
    rep_a = _spot_fleet(seed=2)[0].run()
    rep_b = _spot_fleet(seed=2)[0].run()
    assert rep_a == rep_b
    # a different seed draws different preemption times
    rep_c = _spot_fleet(seed=3)[0].run()
    assert [f for f in rep_c.failures] != [f for f in rep_a.failures]


def test_home_env_never_gets_hazard_injection():
    reg = EnvironmentRegistry()
    reg.register(ExecutionEnvironment("local", hazard_rate=0.0), home=True)
    reg.register(ExecutionEnvironment("spot", speedup=4.0,
                                      hazard_rate=1 / 10))
    sched = SessionScheduler(reg)
    sched.enable_spot_hazards(seed=0, horizon=100.0)
    assert all(env == "spot" for env, _at, _rec in sched._failures)


# -- data gravity ------------------------------------------------------


def test_dollars_dp_keeps_compute_at_the_data():
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.3)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment("near", speedup=6.0,
                                      price_per_hour=1.0), capacity=4)
    reg.register(ExecutionEnvironment("far", speedup=8.0,
                                      price_per_hour=3.0), capacity=4)
    for src in ("local", "near"):
        reg.set_egress(src, "far", 40.0)
        reg.set_egress("far", src, 80.0)
    sched = SessionScheduler(reg)
    rt = sched.add_notebook(remote_sensing_notebook("rs", scenes=3),
                            policy="horizon", use_knowledge=False,
                            objective="dollars", slo=12.0)
    rep = sched.run()
    assert rt.exec_env_seconds.get("near", 0.0) > 0.0
    assert rt.exec_env_seconds.get("far", 0.0) == 0.0
    assert rep.egress_dollars == 0.0
    assert rep.slo_attainment == 1.0


# -- workload factories ------------------------------------------------


def test_workload_factories_execute_end_to_end():
    for nb in (gpu_training_notebook(steps=2, step_cost=5.0),
               remote_sensing_notebook(scenes=2, band_cost=5.0)):
        reg = _reg(remote={"speedup": 10.0})
        rt = HybridRuntime(nb, registry=reg, use_knowledge=False)
        for i in range(len(nb.cells)):
            rt.run_cell(i)
        assert rt.envs[rt.analyzer.home].state  # produced real variables


# -- CLI spec parsers --------------------------------------------------


def test_parse_price_spec():
    assert parse_price_spec("remote:3.0") == ("remote", 3.0)
    for bad in ("remote", "remote:-1", "remote:x"):
        with pytest.raises(ValueError):
            parse_price_spec(bad)


def test_parse_hazard_spec_units():
    env, rate = parse_hazard_spec("spot:6/h")
    assert env == "spot" and rate == pytest.approx(6 / 3600)
    assert parse_hazard_spec("spot:0.1/s")[1] == pytest.approx(0.1)
    # bare rates default to per-hour (the billing-friendly unit)
    assert parse_hazard_spec("spot:6")[1] == pytest.approx(6 / 3600)
    for bad in ("spot", "spot:-6/h", "spot:6/d"):
        with pytest.raises(ValueError):
            parse_hazard_spec(bad)


def test_parse_egress_spec():
    assert parse_egress_spec("remote:local:0.09") \
        == ("remote", "local", 0.09)
    for bad in ("remote:0.09", "remote:local:-1", "a:b:x"):
        with pytest.raises(ValueError):
            parse_egress_spec(bad)
