"""Batched digest/delta plane: bit-identity with the per-leaf path.

The whole-manifest digest (`digest_leaves`) and the fused
digest->compare->gather (`digest_leaves_delta`) must produce digests
bit-identical to per-leaf `tensor_digest` — fig5/fig11 decisions and CAS
chunk keys key off these bits, so any drift is a correctness bug, not a
tolerance question.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _hyp_compat import given, settings, st

from repro.kernels.hash_delta import ops

_DTYPES = (np.float32, np.float64, np.float16, np.int32, np.uint32,
           np.int64, np.int8, np.bool_)


def _leaf(rng: np.random.Generator, spec: int) -> np.ndarray:
    """Deterministic ragged leaf from one sampled integer."""
    dtype = _DTYPES[spec % len(_DTYPES)]
    n = (spec * 131) % 3000          # 0..2999: empty, sub-block, multi-block
    a = rng.standard_normal(n) * 100
    if dtype == np.bool_:
        return a > 0
    return a.astype(dtype)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=0, max_size=12),
       st.integers(0, 2**31 - 1))
def test_batched_digests_match_per_leaf_bit_for_bit(specs, seed):
    rng = np.random.default_rng(seed)
    leaves = [_leaf(rng, s) for s in specs]
    # sprinkle device-resident leaves so packing mixes host and jax parts
    leaves = [jnp.asarray(a) if i % 3 == 2 and a.dtype == np.float32 else a
              for i, a in enumerate(leaves)]
    per = [ops.tensor_digest(a, impl="xla") for a in leaves]
    assert ops.digest_leaves(leaves, impl="xla") == per


def test_batched_matches_interpret_kernel():
    rng = np.random.default_rng(11)
    leaves = [rng.standard_normal(n).astype(np.float32)
              for n in (1, 1000, 1024, 2049, 0, 4096)]
    # per-leaf reference via xla: the interpret Pallas path cannot launch a
    # 0-block grid for the empty leaf, while the batched grid packs it away
    per = [ops.tensor_digest(a, impl="xla") for a in leaves]
    assert ops.digest_leaves(leaves, interpret=True) == per
    nonempty = [a for a in leaves if a.size]
    assert (ops.digest_leaves(nonempty, interpret=True)
            == [ops.tensor_digest(a, interpret=True) for a in nonempty])


def test_delta_reports_exactly_the_changed_leaves():
    rng = np.random.default_rng(5)
    leaves = [rng.standard_normal(300).astype(np.float32) for _ in range(9)]
    prior = ops.digest_leaves(leaves, impl="xla")
    mutated = [a.copy() for a in leaves]
    mutated[2][7] += 1.0
    mutated[6][0] -= 0.5
    priors = list(prior)
    priors[4] = None                 # unknown prior counts as changed
    digests, changed = ops.digest_leaves_delta(mutated, priors, impl="xla")
    assert changed == [2, 4, 6]
    assert digests == ops.digest_leaves(mutated, impl="xla")


def test_delta_empty_and_all_unchanged():
    assert ops.digest_leaves_delta([], []) == ([], [])
    rng = np.random.default_rng(6)
    leaves = [rng.standard_normal(64).astype(np.float32) for _ in range(3)]
    prior = ops.digest_leaves(leaves, impl="xla")
    digests, changed = ops.digest_leaves_delta(leaves, prior, impl="xla")
    assert changed == [] and digests == prior


def test_fused_compare_kernel_matches_oracle():
    from repro.kernels.hash_delta.kernel import block_hash_compare_kernel
    from repro.kernels.hash_delta.ref import block_hash_compare_ref

    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.integers(0, 2**32, (6, ops.BLOCK), dtype=np.uint32))
    w = jnp.asarray(ops._W)
    h_ref = np.asarray(block_hash_compare_ref(
        x, w, jnp.zeros((6, ops.LANES), jnp.uint32),
        jnp.zeros((6, 1), jnp.uint32))[0])
    prior = jnp.asarray(h_ref.copy())
    prior = prior.at[3, 0].add(np.uint32(1))        # one block differs
    has = jnp.ones((6, 1), jnp.uint32)
    has = has.at[5, 0].set(0)                       # one block has no prior
    hk, ck = block_hash_compare_kernel(x, w, prior, has, interpret=True)
    hr, cr = block_hash_compare_ref(x, w, prior, has)
    np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(hk), h_ref)
    assert list(np.asarray(ck)[:, 0]) == [0, 0, 0, 1, 0, 1]


def test_host_sync_counter_is_o1_for_batched():
    rng = np.random.default_rng(13)
    leaves = [rng.standard_normal(256).astype(np.float32) for _ in range(40)]
    ops.reset_host_syncs()
    for a in leaves:
        ops.tensor_digest(a, impl="xla")
    assert ops.HOST_SYNCS == 40
    ops.reset_host_syncs()
    ops.digest_leaves(leaves, impl="xla")
    assert ops.HOST_SYNCS == 1
    ops.reset_host_syncs()
    ops.digest_leaves_delta(leaves, [None] * 40, impl="xla")
    assert ops.HOST_SYNCS == 1


def test_staging_reuse_cannot_corrupt_consecutive_calls():
    # back-to-back batched digests reuse the same staging buffer; the
    # second call must not disturb results derived from the first
    rng = np.random.default_rng(21)
    a = [rng.standard_normal(2000).astype(np.float32) for _ in range(4)]
    b = [rng.standard_normal(2000).astype(np.float32) for _ in range(4)]
    da1 = ops.digest_leaves(a, impl="xla")
    db = ops.digest_leaves(b, impl="xla")
    da2 = ops.digest_leaves(a, impl="xla")
    assert da1 == da2 and da1 != db


def test_object_dtype_leaf_is_rejected_not_misdigested():
    from repro.core.reducer import StateReducer
    with pytest.raises(TypeError):
        StateReducer._hashable_leaf(np.array([object()], dtype=object))
