"""Dry-run plumbing units: HLO collective parsing + cost extrapolation.

(Imports only the pure helpers — importing repro.launch.dryrun would set
XLA_FLAGS, which must not happen inside the test process; the helpers are
re-implemented import-free via importlib machinery on the source file.)
"""
import importlib.util
import os
import types

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src", "repro", "launch", "dryrun.py")


def _load_helpers():
    src = open(_SRC).read()
    # strip the env mutation + jax import side effects: keep pure helpers only
    start = src.index("_DTYPE_BYTES")
    end = src.index("def _make_mesh")
    body = src[start:end]
    header = "import re\n\n"
    mod = types.ModuleType("dryrun_helpers")
    exec(header + body, mod.__dict__)
    return mod


H = _load_helpers()

HLO = """
  %all-reduce.1 = f32[64,4096]{1,0} all-reduce(%x), replica_groups=[4,8]<=[32]
  %all-gather.2 = bf16[2048,128]{1,0} all-gather(%y), replica_groups=[2,16]<=[32]
  %reduce-scatter.3 = f32[128]{0} reduce-scatter(%z), replica_groups={{0,1,2,3}}
  %all-reduce-start.4 = f32[100]{0} all-reduce-start(%w), replica_groups=[1,2]<=[2]
  %all-reduce-done.4 = f32[100]{0} all-reduce-done(%all-reduce-start.4)
  %collective-permute.5 = bf16[10,10]{1,0} collective-permute(%p), source_target_pairs={{0,1}}
"""


def test_shape_bytes():
    assert H._shape_bytes("f32[64,4096]") == 64 * 4096 * 4
    assert H._shape_bytes("(f32[10], bf16[20])") == 10 * 4 + 20 * 2
    assert H._shape_bytes("pred[8]") == 8


def test_parse_collectives():
    out = H.parse_collectives(HLO)
    per = out["per_op"]
    assert per["all-reduce"]["count"] == 2          # start counted, done not
    assert per["all-gather"]["count"] == 1
    assert per["reduce-scatter"]["count"] == 1
    assert per["collective-permute"]["count"] == 1
    ar = 64 * 4096 * 4
    assert abs(per["all-reduce"]["wire_bytes"] -
               (2 * 7 / 8 * ar + 2 * 1 / 2 * 100 * 4)) < 1e-6
    # reduce-scatter: (group-1) x result bytes
    assert per["reduce-scatter"]["wire_bytes"] == 3 * 128 * 4


def test_combine_extrapolation():
    base = {"flops": 100.0, "bytes": 10.0, "wire": 4.0,
            "per_op": {"all-reduce": {"count": 2, "wire_bytes": 4.0}}}
    body = {"flops": 160.0, "bytes": 16.0, "wire": 7.0,
            "per_op": {"all-reduce": {"count": 3, "wire_bytes": 7.0}}}
    out = H._combine(base, body, units=10)
    # delta=60 -> nonloop=40 -> total = 40 + 10*60
    assert out["flops"] == 40 + 600
    assert out["bytes"] == 4 + 60
    assert out["wire"] == 1 + 30
    assert out["per_op"]["all-reduce"]["count"] == 1 + 10


def test_roofline_analyze():
    spec = importlib.util.spec_from_file_location(
        "roofline", _SRC.replace("dryrun.py", "roofline.py"))
    R = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(R)
    rec = {"arch": "a", "shape": "train_4k", "mesh": "pod_16x16",
           "applicable": True, "kind": "train", "n_devices": 256,
           "flops_per_device": 197e12, "bytes_accessed_per_device": 819e9,
           "wire_bytes_per_device": 100e9, "tokens_per_step": 1000,
           "active_params": 1e9, "memory": {"peak": 8e9, "fits_hbm": True}}
    a = R.analyze(rec)
    assert abs(a["compute_s"] - 1.0) < 1e-9
    assert abs(a["memory_s"] - 1.0) < 1e-9
    assert abs(a["collective_s"] - 2.0) < 1e-9
    assert a["dominant"] == "collective"
    assert abs(a["roofline_frac"] - 0.5) < 1e-9
