"""Event loop + clock protocol conformance (tests both SimClock and
WallClock under the same suite: monotonicity, timer ordering, zero-delay
events — the contract the fleet plane is built on)."""
import pytest

from repro.core import EventLoop, SimClock, WallClock

# WallClock tests sleep for real: keep the delays tiny
SCALE = {"sim": 1.0, "wall": 0.005}


def make_clock(kind: str):
    return SimClock() if kind == "sim" else WallClock()


# ----------------------------------------------------------------------
# clock conformance suite (shared across both implementations)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["sim", "wall"])
def test_clock_now_monotone_under_loop(kind):
    loop = EventLoop(make_clock(kind))
    seen = []
    for d in (3, 1, 2, 0):
        loop.call_later(d * SCALE[kind], lambda: seen.append(loop.now()))
    loop.run()
    assert seen == sorted(seen)
    assert len(seen) == 4


@pytest.mark.parametrize("kind", ["sim", "wall"])
def test_clock_timer_ordering(kind):
    """Timers scheduled out of order fire in due-time order."""
    loop = EventLoop(make_clock(kind))
    fired = []
    loop.call_later(3 * SCALE[kind], lambda: fired.append("c"))
    loop.call_later(1 * SCALE[kind], lambda: fired.append("a"))
    loop.call_later(2 * SCALE[kind], lambda: fired.append("b"))
    loop.run()
    assert fired == ["a", "b", "c"]


@pytest.mark.parametrize("kind", ["sim", "wall"])
def test_clock_zero_delay_events_fifo(kind):
    """Same-instant events fire in scheduling order (seq breaks the tie)."""
    loop = EventLoop(make_clock(kind))
    fired = []
    for i in range(5):
        loop.call_later(0.0, lambda i=i: fired.append(i))
    t0 = loop.now()
    loop.run()
    assert fired == [0, 1, 2, 3, 4]
    if kind == "sim":
        assert loop.now() == t0          # zero delay advances nothing


@pytest.mark.parametrize("kind", ["sim", "wall"])
def test_clock_advance_protocol(kind):
    """advance() returns a time >= the pre-call now; a real clock refuses
    to skip ahead (that no-op is how the event loop knows to sleep)."""
    clock = make_clock(kind)
    before = clock.now()
    after = clock.advance(0.01 if kind == "wall" else 5.0)
    assert after >= before
    if kind == "sim":
        assert after == before + 5.0
    else:
        assert after < before + 0.01     # no actual sleep happened


# ----------------------------------------------------------------------
# event loop semantics (simulated clock: fully deterministic)
# ----------------------------------------------------------------------

def test_priority_breaks_same_time_ties():
    loop = EventLoop()
    fired = []
    loop.call_at(1.0, lambda: fired.append("low"), priority=5)
    loop.call_at(1.0, lambda: fired.append("high"), priority=-5)
    loop.call_at(1.0, lambda: fired.append("mid"), priority=0)
    loop.run()
    assert fired == ["high", "mid", "low"]


def test_cancelled_events_are_skipped():
    loop = EventLoop()
    fired = []
    ev = loop.call_later(1.0, lambda: fired.append("cancelled"))
    loop.call_later(2.0, lambda: fired.append("kept"))
    ev.cancel()
    loop.run()
    assert fired == ["kept"]
    assert loop.pending() == 0


def test_events_scheduled_during_run_fire():
    loop = EventLoop()
    fired = []

    def first():
        fired.append("first")
        loop.call_later(1.0, lambda: fired.append("nested"))

    loop.call_later(1.0, first)
    end = loop.run()
    assert fired == ["first", "nested"]
    assert end == 2.0


def test_run_until_stops_and_advances():
    loop = EventLoop()
    fired = []
    loop.call_at(1.0, lambda: fired.append(1))
    loop.call_at(10.0, lambda: fired.append(10))
    t = loop.run(until=5.0)
    assert fired == [1]
    assert t == 5.0
    loop.run()
    assert fired == [1, 10]


def test_recurring_timer_stops_on_false_and_cancel():
    loop = EventLoop()
    ticks = []
    loop.every(1.0, lambda: ticks.append(loop.now()) or
               (None if len(ticks) < 3 else False))
    loop.run()
    assert ticks == [1.0, 2.0, 3.0]

    loop2 = EventLoop()
    ticks2 = []
    handle = loop2.every(1.0, lambda: ticks2.append(loop2.now()))
    loop2.call_at(2.5, handle.cancel)
    loop2.run()
    assert ticks2 == [1.0, 2.0]


def test_generator_process_yields_delays():
    loop = EventLoop()
    trace = []

    def proc(tag, pause):
        trace.append((tag, loop.now()))
        yield pause
        trace.append((tag, loop.now()))
        yield pause
        trace.append((tag, loop.now()))

    loop.process(proc("a", 2.0))
    loop.process(proc("b", 3.0), delay=1.0)
    loop.run()
    assert trace == [("a", 0.0), ("b", 1.0), ("a", 2.0), ("b", 4.0),
                     ("a", 4.0), ("b", 7.0)]


def test_loop_is_deterministic():
    """Two identical schedules produce the identical firing sequence."""

    def run_once():
        loop = EventLoop()
        fired = []
        for i, (t, p) in enumerate([(2.0, 0), (1.0, 3), (1.0, -1),
                                    (2.0, 0), (0.5, 9)]):
            loop.call_at(t, lambda i=i: fired.append((i, loop.now())),
                         priority=p)
        loop.run()
        return fired

    assert run_once() == run_once()
