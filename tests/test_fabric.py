"""Environment fabric: registry, N-env placement, pipelined migration,
multi-session scheduling (beyond the paper's local/remote dyad)."""
import numpy as np
import pytest

from repro.core import (
    EnvironmentRegistry, ExecutionEnvironment, HybridRuntime, Link,
    MigrationEngine, Notebook, PipelinedMigrationEngine, SessionScheduler,
    StateReducer, simulate, synthetic_loops_trace,
)
from repro.core import telemetry as T


def _three_env_registry(**kw):
    reg = EnvironmentRegistry(default_bandwidth=1e9, default_latency=0.1, **kw)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=4)
    reg.register(ExecutionEnvironment("gpu-cloud", speedup=8.0), capacity=2)
    reg.register(ExecutionEnvironment("tpu-mesh", speedup=40.0), capacity=1)
    reg.connect("local", "gpu-cloud", bandwidth=5e8, latency=0.3)
    reg.connect("local", "tpu-mesh", bandwidth=1e8, latency=1.0)
    reg.connect("gpu-cloud", "tpu-mesh", bandwidth=1e9, latency=0.2)
    return reg


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------

def test_registry_links_and_lookup():
    reg = _three_env_registry()
    assert reg.home == "local"
    assert set(reg.names()) == {"local", "gpu-cloud", "tpu-mesh"}
    assert reg.candidates() == ["gpu-cloud", "tpu-mesh"]
    assert reg.link("local", "gpu-cloud") == Link(5e8, 0.3)
    assert reg.link("gpu-cloud", "local") == Link(5e8, 0.3)   # symmetric
    assert reg.transfer_seconds("local", "tpu-mesh", 1e8) == 1.0 + 1.0
    assert reg.transfer_seconds("local", "local", 1e9) == 0.0
    assert len(reg.pairs()) == 6


def test_registry_storage_env_not_placeable():
    reg = _three_env_registry()
    reg.register(ExecutionEnvironment("disk", kind="storage"))
    assert "disk" in reg
    assert "disk" not in reg.compute_envs()
    assert "disk" not in reg.candidates()


def test_registry_clone_topology_fresh_namespaces():
    reg = _three_env_registry()
    reg["local"].execute("x = 1")
    clone = reg.clone_topology()
    assert set(clone.names()) == set(reg.names())
    assert clone.home == "local"
    assert clone.link("local", "tpu-mesh") == reg.link("local", "tpu-mesh")
    assert "x" not in clone["local"].state.ns          # fresh namespace
    assert clone.capacity("gpu-cloud") == 2


def test_legacy_envs_dict_adapts():
    reg = EnvironmentRegistry.from_envs(
        {"local": ExecutionEnvironment("local"),
         "remote": ExecutionEnvironment("remote", speedup=10.0)},
        bandwidth=1e8, latency=0.5)
    assert reg.home == "local" and reg.candidates() == ["remote"]
    assert reg.transfer_seconds("local", "remote", 1e8) == 0.5 + 1.0


# ----------------------------------------------------------------------
# N-env migration: tombstones on every receiver (delta §II-D generalized)
# ----------------------------------------------------------------------

def test_tombstones_propagate_to_all_synced_receivers():
    reg = _three_env_registry()
    a, b, c = reg["local"], reg["gpu-cloud"], reg["tpu-mesh"]
    eng = MigrationEngine(StateReducer("zlib"), registry=reg)
    a.execute("x = [1, 2, 3]\ny = 'keep'")
    eng.migrate(a, b, names={"x", "y"})
    eng.migrate(a, c, names={"x", "y"})
    assert "x" in b.state.ns and "x" in c.state.ns

    a.execute("del x")                     # deleted on the source
    res = eng.migrate(a, b, None)          # next sync carries the tombstone
    assert "x" in res.deleted
    # dropped on *every* synced receiver, not just the migration target
    assert "x" not in b.state.ns
    assert "x" not in c.state.ns
    # and its digest is evicted from every synced view
    for env_name, view in eng.synced.items():
        assert "x" not in view, env_name
    # unrelated names survive everywhere
    assert b.state["y"] == "keep" and c.state["y"] == "keep"


# ----------------------------------------------------------------------
# cost-matrix policy over N envs
# ----------------------------------------------------------------------

def _heavy_nb():
    nb = Notebook("fabric-nb")
    nb.add_cell("import numpy as np\nxs = np.arange(64.0)", cost=0.2)
    nb.add_cell("ys = xs * 2", cost=0.3)
    nb.add_cell("z = float((ys ** 2).sum())", cost=120.0)
    nb.add_cell("w = z + 1", cost=0.1)
    return nb


def test_cost_matrix_places_heavy_cell_on_third_env():
    nb = _heavy_nb()
    rt = HybridRuntime(nb, registry=_three_env_registry(), policy="cost",
                       use_knowledge=False)
    for _ in range(2):
        for i in range(len(nb.cells)):
            rt.run_cell(i)
    rt.close()
    execs = [(m.cell_id, m.payload["env"]) for m in rt.bus.messages()
             if m.type == T.CELL_EXECUTION_STARTED]
    envs_used = {env for _, env in execs}
    heavy_envs = {env for cid, env in execs if cid == nb.cells[2].cell_id}
    # the heavy cell lands on the fastest env despite its slower link
    assert "tpu-mesh" in heavy_envs
    # cheap cells are not dragged to an accelerator for nothing
    assert any(env == "local" for _, env in execs)
    assert rt.current_env == "local"                  # returned home
    assert any("cost-matrix" in a for a in nb.cells[2].annotations)
    assert envs_used <= {"local", "gpu-cloud", "tpu-mesh"}


def test_runtime_accepts_n_envs_with_block_policy():
    nb = _heavy_nb()
    rt = HybridRuntime(nb, registry=_three_env_registry(), policy="block",
                       use_knowledge=False)
    for _ in range(3):
        for i in range(len(nb.cells)):
            rt.run_cell(i)
    rt.close()
    local_only = 3 * sum(c.cost for c in nb.cells)
    assert rt.clock.now() < local_only
    assert rt.migrations > 0


# ----------------------------------------------------------------------
# pipelined engine
# ----------------------------------------------------------------------

def _pair():
    reg = EnvironmentRegistry(default_bandwidth=1e6, default_latency=1.0)
    l = reg.register(ExecutionEnvironment("local"), home=True)
    r = reg.register(ExecutionEnvironment("remote", speedup=10.0))
    l.execute("import numpy as np\n"
              "data = np.arange(250_000, dtype=np.float64)\n"
              "def use(x):\n    return float(x.sum())\n")
    return reg, l, r


def test_prefetch_overlaps_execution_on_clock():
    reg, l, r = _pair()
    eng = PipelinedMigrationEngine(StateReducer("none"), registry=reg)
    p = eng.begin_prefetch(l, r, "out = use(data)", now=0.0)
    assert p is not None and p.ready_at > 1.0          # ~2MB over 1MB/s
    # execution covered the whole transfer: nothing left to charge
    res = eng.migrate(l, r, "out = use(data)", now=p.ready_at + 1.0)
    assert res.seconds == 0.0
    assert "data" in res.prefetched
    r.execute("out = use(data)")
    assert r.state["out"] == float(np.arange(250_000, dtype=np.float64).sum())


def test_prefetch_partially_covered_charges_remainder():
    reg, l, r = _pair()
    eng = PipelinedMigrationEngine(StateReducer("none"), registry=reg)
    p = eng.begin_prefetch(l, r, "out = use(data)", now=0.0)
    res = eng.migrate(l, r, "out = use(data)", now=0.5)
    assert res.seconds == pytest.approx(p.ready_at - 0.5)


def test_prefetch_invalidated_name_resent_fresh():
    reg, l, r = _pair()
    eng = PipelinedMigrationEngine(StateReducer("none"), registry=reg)
    eng.begin_prefetch(l, r, "out = use(data)", now=0.0)
    # the overlapped cell redefines `data`: the prefetched copy is stale
    l.execute("data = np.ones(10)")
    eng.invalidate("local", {"data"})
    res = eng.migrate(l, r, "out = use(data)", now=100.0)
    assert "data" in res.names and "data" not in res.prefetched
    assert res.seconds > 0.0                           # charged synchronously
    r.execute("out = use(data)")
    assert r.state["out"] == 10.0                      # fresh value, not stale


def test_pipelined_runtime_beats_synchronous_on_blocks():
    def total(pipeline):
        nb = Notebook("pipe-nb")
        nb.add_cell("import numpy as np\n"
                    "state = np.arange(250_000, dtype=np.float64)", cost=3.0)
        nb.add_cell("a = float(state.sum())", cost=40.0)
        nb.add_cell("b = a * 2", cost=40.0)
        rt = HybridRuntime(
            nb, registry=EnvironmentRegistry.two_env(
                remote_speedup=10.0, bandwidth=1e6, latency=0.5),
            policy="block", use_knowledge=False, pipeline=pipeline,
            reducer=StateReducer("none"))
        for _ in range(3):
            for i in range(len(nb.cells)):
                rt.run_cell(i)
        rt.close()
        return rt.clock.now()

    sync, pipe = total(False), total(True)
    assert pipe < sync          # transfer overlapped execution on the clock


# ----------------------------------------------------------------------
# chunk-manifest exchange (CAS state plane)
# ----------------------------------------------------------------------

def test_small_mutation_ships_one_chunk_not_the_array():
    reg = EnvironmentRegistry.two_env()
    l, r = reg["local"], reg["remote"]
    eng = MigrationEngine(StateReducer("none", chunk_bytes=16 << 10),
                          registry=reg)
    l.state["big"] = np.arange(1 << 18, dtype=np.float32)     # 1 MiB
    first = eng.migrate(l, r, names={"big"})
    assert first.nbytes > (1 << 20)
    l.state["big"][7] += 1.0                                  # one element
    second = eng.migrate(l, r, names={"big"})
    assert "big" in second.names                              # name is stale
    assert second.nbytes < first.nbytes / 10                  # ~one chunk
    np.testing.assert_array_equal(r.state["big"], l.state["big"])


def test_receiver_store_dedups_across_names():
    """The same content under a second name ships only a manifest."""
    reg = EnvironmentRegistry.two_env()
    l, r = reg["local"], reg["remote"]
    eng = MigrationEngine(StateReducer("none", chunk_bytes=16 << 10),
                          registry=reg)
    l.state["a"] = np.arange(1 << 16, dtype=np.float64)
    first = eng.migrate(l, r, names={"a"})
    l.state["b"] = l.state["a"].copy()                        # same content
    second = eng.migrate(l, r, names={"b"})
    assert second.nbytes < first.nbytes / 10
    np.testing.assert_array_equal(r.state["b"], l.state["a"])


def test_sessions_share_dataset_chunks_through_scheduler():
    def total_bytes(share: bool) -> int:
        reg = EnvironmentRegistry(default_bandwidth=1e9, default_latency=0.1)
        reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
        reg.register(ExecutionEnvironment("gpu-cloud", speedup=10.0),
                     capacity=2)
        sched = SessionScheduler(reg, share_chunks=share)
        rts = []
        for i in range(3):
            nb = Notebook(f"shared-{i}")
            nb.add_cell("import numpy as np\n"
                        "ds = np.arange(100_000, dtype=np.float64)", cost=0.5)
            nb.add_cell("m = float(ds.sum())", cost=120.0)
            rts.append(sched.add_notebook(
                nb, policy="cost", use_knowledge=False,
                reducer=StateReducer("none", chunk_bytes=16 << 10)))
        sched.run()
        for rt in rts:
            got = (rt.envs["local"].state.get("m")
                   or rt.envs["gpu-cloud"].state.get("m"))
            assert got == float(np.arange(100_000, dtype=np.float64).sum())
        return sum(m.nbytes for rt in rts for m in rt.engine.log)

    isolated, shared = total_bytes(False), total_bytes(True)
    # 3 sessions move the dataset: isolated pays 3x, shared pays ~1x
    assert shared < isolated / 2


# ----------------------------------------------------------------------
# scheduler
# ----------------------------------------------------------------------

def test_scheduler_queues_on_saturated_env():
    reg = _three_env_registry()
    sched = SessionScheduler(reg)
    for i in range(3):
        sched.add_notebook(_heavy_nb(), policy="cost", use_knowledge=False)
    report = sched.run()
    assert len(report.sessions) == 3
    assert all(s.cells_run == 4 for s in report.sessions)
    # tpu-mesh has capacity 1 and every session wants its heavy cell there:
    # somebody must have queued
    assert report.queue_events > 0
    assert report.total_queue_wait > 0.0
    assert report.makespan >= max(s.makespan - s.queue_wait
                                  for s in report.sessions)
    assert 0.0 < report.env_utilization["tpu-mesh"] <= 1.0


def test_scheduler_capacity_two_waits_less_than_one():
    def wait_with_capacity(cap):
        reg = EnvironmentRegistry(default_bandwidth=1e9, default_latency=0.1)
        reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
        reg.register(ExecutionEnvironment("gpu-cloud", speedup=10.0),
                     capacity=cap)
        sched = SessionScheduler(reg)
        for _ in range(4):
            sched.add_notebook(_heavy_nb(), policy="cost", use_knowledge=False)
        return sched.run().total_queue_wait

    assert wait_with_capacity(2) < wait_with_capacity(1)


# ----------------------------------------------------------------------
# simulator registry adapter
# ----------------------------------------------------------------------

def test_simulator_registry_matches_scalars():
    tr = synthetic_loops_trace()
    for policy in ("single", "block"):
        scalar = simulate(tr, policy, migration_time=1.0, remote_speedup=50)
        reg = EnvironmentRegistry.two_env(
            remote_speedup=50, bandwidth=float("inf"), latency=1.0)
        fabric = simulate(tr, policy, registry=reg)
        assert fabric.total_seconds == pytest.approx(scalar.total_seconds)
        assert fabric.migrations == scalar.migrations


# ----------------------------------------------------------------------
# scheduler prediction telemetry
# ----------------------------------------------------------------------

def test_scheduler_reports_prediction_telemetry():
    reg = _three_env_registry()
    sched = SessionScheduler(reg)
    for _ in range(2):
        sched.add_notebook(_heavy_nb(), policy="cost", use_knowledge=False,
                           pipeline=True)
    report = sched.run()
    # per-session hit-rate fields exist and are sane
    for s in report.sessions:
        assert s.prediction_total >= 0
        assert 0.0 <= s.prediction_hit_rate <= 1.0
    assert 0.0 <= report.prediction_hit_rate <= 1.0
    # predicted per-env load telemetry sits next to the realized one
    assert set(report.predicted_env_seconds) == set(reg.names())
    assert sum(report.predicted_env_seconds.values()) > 0.0
    assert set(report.actual_env_seconds) == set(reg.names())
    # sessions were closed -> their bus subscribers were detached
    for s in sched._sessions:
        assert s.runtime.bus.subscriber_count("telemetry") == 0
