"""Fault tolerance: heartbeat failures, stragglers, restart, elastic rescale."""
from repro.core.simclock import SimClock
from repro.distributed.fault import Coordinator, ElasticTrainer


def _trainer(fail_at=None, straggle=None, spares=0, workers=4):
    clock = SimClock()
    coord = Coordinator([f"w{i}" for i in range(workers)], clock,
                        beat_interval=1.0, miss_threshold=3,
                        straggler_patience=2)
    saved = {"step": 0}
    log = {"steps_run": []}
    detected = set()   # once the coordinator declares a worker dead, the
                       # "replacement node" behaves normally again

    def step_fn(step, world):
        log["steps_run"].append((step, tuple(world)))
        out = {}
        for w in world:
            if (fail_at is not None and w == fail_at["worker"]
                    and step >= fail_at["step"] and w not in detected):
                if not coord.workers[w].alive:
                    detected.add(w)
                continue  # crashed: no duration, no heartbeat
            t = 1.0
            if straggle and w == straggle["worker"] and step >= straggle["from"]:
                t = straggle["factor"]
            out[w] = t
        return out

    def save_fn(step):
        saved["step"] = step

    def restore_fn():
        for w in list(coord.workers):
            if not coord.workers[w].alive:
                detected.add(w)
        return saved["step"]

    rescales = {"worlds": []}

    def rescale_fn(world):
        rescales["worlds"].append(tuple(world))

    et = ElasticTrainer(coord, step_fn=step_fn, save_fn=save_fn,
                        restore_fn=restore_fn, rescale_fn=rescale_fn,
                        checkpoint_every=5, spares=spares)
    return et, coord, saved, log, rescales


def test_failure_triggers_restart_from_checkpoint():
    et, coord, saved, log, _ = _trainer(fail_at={"step": 7, "worker": "w2"},
                                        spares=1)
    res = et.run(12)
    assert res["restarts"] == 1
    kinds = [e.kind for e in res["events"]]
    assert "failure" in kinds
    # training resumed from the last checkpoint (step 5), not from scratch
    resumed = [s for s, _ in log["steps_run"]]
    assert resumed.count(5) >= 2 and res["steps"] == 12


def test_failure_without_spares_rescales():
    et, coord, saved, log, rescales = _trainer(
        fail_at={"step": 7, "worker": "w2"}, spares=0)
    res = et.run(12)
    assert res["rescales"] == 1
    assert rescales["worlds"] and len(rescales["worlds"][0]) == 3
    assert all("w2" not in world for _, world in log["steps_run"][-2:])


def test_failure_with_spare_keeps_world_size():
    et, coord, saved, log, rescales = _trainer(
        fail_at={"step": 7, "worker": "w2"}, spares=1)
    res = et.run(12)
    assert res["rescales"] == 0
    assert "restart" in [e.kind for e in res["events"]]
    assert len(log["steps_run"][-1][1]) == 4


def test_straggler_evicted():
    et, coord, saved, log, rescales = _trainer(
        straggle={"worker": "w3", "from": 4, "factor": 30.0}, spares=0,
        workers=5)
    res = et.run(10)
    kinds = [e.kind for e in res["events"]]
    assert "straggler" in kinds
    assert all("w3" not in world for _, world in log["steps_run"][-2:])


def test_no_faults_clean_run():
    et, coord, saved, log, _ = _trainer()
    res = et.run(8)
    assert res["restarts"] == 0 and res["rescales"] == 0
    assert saved["step"] == 5
    assert len(log["steps_run"]) == 8
