"""Fleet plane: env lifecycle, arrivals/think-time, failure recovery via
CAS checkpoints, autoscaling, arbiter pruning, and deterministic replay."""
import numpy as np
import pytest

from repro.core import (
    AutoscalePolicy, CapacityArbiter, EnvironmentRegistry,
    ExecutionEnvironment, Notebook, SessionScheduler, WorkloadTrace,
)
from repro.distributed.fault import Coordinator


def make_nb(tag="", heavy=100.0):
    nb = Notebook(f"fleet{tag}")
    nb.add_cell("import numpy as np\n"
                "data = np.arange(50_000, dtype=np.float64)", cost=4.0)
    nb.add_cell("a = float(data.sum())", cost=heavy)
    nb.add_cell("b = a * 2", cost=heavy)
    nb.add_cell("report = b + a", cost=0.2)
    return nb


def make_reg(*, burst=False):
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.3)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment("gpu", speedup=10.0), capacity=1)
    if burst:
        reg.register(ExecutionEnvironment(
            "burst", speedup=10.0, status="down", cold_start=5.0,
            idle_timeout=10.0), capacity=1)
    return reg


# ----------------------------------------------------------------------
# lifecycle state machine
# ----------------------------------------------------------------------

def test_lifecycle_transitions_and_audit_log():
    reg = make_reg()
    reg.set_status("gpu", "draining", now=1.0)
    reg.set_status("gpu", "down", now=2.0)
    reg.set_status("gpu", "provisioning", now=3.0)
    reg.set_status("gpu", "up", now=4.0)
    assert [(e[1], e[3]) for e in reg.lifecycle_log] == [
        ("gpu", "draining"), ("gpu", "down"), ("gpu", "provisioning"),
        ("gpu", "up")]


def test_lifecycle_illegal_transition_raises():
    env = ExecutionEnvironment("x", status="down")
    with pytest.raises(ValueError, match="illegal lifecycle transition"):
        env.set_status("up")          # down must re-provision first


def test_cold_start_sets_ready_at():
    env = ExecutionEnvironment("x", status="down", cold_start=7.5)
    env.set_status("provisioning", now=10.0)
    assert env.ready_at == 17.5
    assert env.placeable_now()        # provisioning is placeable (priced)


def test_down_and_failed_envs_are_not_placement_candidates():
    reg = make_reg(burst=True)
    assert "burst" not in reg.compute_envs()
    assert reg.candidates() == ["gpu"]
    reg.set_status("gpu", "failed")
    assert reg.candidates() == []


def test_retire_removes_env_and_links():
    reg = make_reg(burst=True)
    reg.connect("local", "burst", bandwidth=1e9, latency=0.1)
    reg.retire("burst")
    assert "burst" not in reg
    assert ("local", "burst") not in reg._links
    with pytest.raises(ValueError, match="cannot retire the home"):
        reg.retire("local")


def test_clone_topology_preserves_lifecycle_state():
    reg = make_reg(burst=True)
    reg.set_status("burst", "provisioning", now=3.0)
    clone = reg.clone_topology()
    assert clone["burst"].status == "provisioning"
    assert clone["burst"].ready_at == reg["burst"].ready_at
    assert clone["burst"].cold_start == 5.0
    assert clone["burst"].idle_timeout == 10.0


# ----------------------------------------------------------------------
# arrivals + think-time
# ----------------------------------------------------------------------

def test_arrivals_and_think_time_show_in_report():
    sched = SessionScheduler(make_reg())
    sched.add_notebook(make_nb("-0"), policy="cost", use_knowledge=False)
    sched.add_notebook(make_nb("-1"), policy="cost", use_knowledge=False,
                       arrival=50.0, think=[2.0, 3.0, 4.0])
    rep = sched.run()
    s0, s1 = rep.sessions
    assert s0.arrival == 0.0 and s0.think_time == 0.0
    assert s1.arrival == 50.0
    assert s1.makespan >= 50.0            # clock absorbed the arrival offset
    assert s1.think_time == pytest.approx(9.0)
    assert rep.total_think_time == pytest.approx(9.0)


def test_workload_trace_poisson_is_seeded():
    a = WorkloadTrace.poisson(4, rate=0.2, think_mean=3.0,
                              cells_per_session=5, seed=42)
    b = WorkloadTrace.poisson(4, rate=0.2, think_mean=3.0,
                              cells_per_session=5, seed=42)
    c = WorkloadTrace.poisson(4, rate=0.2, think_mean=3.0,
                              cells_per_session=5, seed=43)
    assert a == b
    assert a != c
    assert a.arrivals[0] == 0.0 and a.arrivals == sorted(a.arrivals)


def test_static_trace_is_the_degenerate_instance():
    """Zero arrivals gap + zero think-time must reproduce the plain run."""

    def run(workload):
        sched = SessionScheduler(make_reg())
        for i in range(3):
            sched.add_notebook(make_nb(f"-{i}"), policy="cost",
                               use_knowledge=False)
        if workload is not None:
            sched.set_workload(workload)
        return sched.run()

    assert run(None) == run(WorkloadTrace.static(3))


# ----------------------------------------------------------------------
# failure recovery
# ----------------------------------------------------------------------

def _failure_run(mode, fail_at=15.0):
    sched = SessionScheduler(make_reg())
    sched.enable_recovery(mode, interval=5.0)
    rt = sched.add_notebook(make_nb(f"-{mode}"), policy="cost",
                            use_knowledge=False, think=[1.0] * 4)
    # cell 2 runs on gpu roughly [13, 23): t=15 is mid-cell, and the t=5
    # checkpoint tick has already captured the state through cell 1
    sched.inject_failure("gpu", at=fail_at, recover_after=10.0)
    rep = sched.run()
    return sched, rt, rep


def test_mid_cell_failure_triggers_recovery_and_completes():
    sched, rt, rep = _failure_run("rerun")
    assert rep.recoveries == 1
    assert rep.failures == [("gpu", 15.0)]
    s = rep.sessions[0]
    assert s.cells_run == 4
    # the plan replayed end-to-end: final state is correct on home
    want = float(np.arange(50_000, dtype=np.float64).sum()) * 3
    assert rt.envs["local"].state.get("report") == want
    # heartbeat audit trail detected the death (fault.py Coordinator)
    assert any(kind == "failure" and worker == "gpu"
               for _, kind, worker, _ in rep.fault_events)


def test_checkpoint_recovery_beats_rerun_on_makespan():
    _, rt_r, rep_rerun = _failure_run("rerun")
    _, rt_c, rep_ckpt = _failure_run("checkpoint")
    assert rep_ckpt.recoveries == 1 and rep_rerun.recoveries == 1
    assert rep_ckpt.checkpoints > 0
    assert rep_ckpt.makespan < rep_rerun.makespan
    want = float(np.arange(50_000, dtype=np.float64).sum()) * 3
    assert rt_c.envs["local"].state.get("report") == want


def test_failure_before_first_checkpoint_falls_back_to_rerun():
    """A failure that lands before any checkpoint tick restores nothing —
    the session replays its whole plan and still finishes correctly."""
    # cell 1's step fires at ~t=1.7 and simulates through ~t=11.7: the
    # failure at t=8 interrupts it before the first checkpoint tick (t=5,
    # which only fires after the in-flight step) has anything to capture
    _, rt, rep = _failure_run("checkpoint", fail_at=8.0)
    assert rep.recoveries == 1
    assert rep.sessions[0].cells_run == 4
    want = float(np.arange(50_000, dtype=np.float64).sum()) * 3
    assert rt.envs["local"].state.get("report") == want


def test_failed_env_recovers_after_reprovision():
    sched, _, rep = _failure_run("rerun")
    # recover_after=10 + cold start: the env came back up
    assert sched.registry["gpu"].status == "up"
    transitions = [(e[1], e[3]) for e in rep.lifecycle_events]
    assert ("gpu", "failed") in transitions
    assert ("gpu", "provisioning") in transitions
    assert ("gpu", "up") in transitions


def test_rerun_recovery_does_not_double_execute_state():
    """Replay must start from fresh namespaces: a non-idempotent cell
    (append/increment) run twice against surviving state would corrupt the
    result."""
    nb = Notebook("nonidem")
    nb.add_cell("acc = []", cost=2.0)
    nb.add_cell("acc.append(1)", cost=100.0)
    nb.add_cell("acc.append(2)", cost=100.0)
    nb.add_cell("total = len(acc)", cost=0.2)
    sched = SessionScheduler(make_reg())
    sched.enable_recovery("rerun")
    rt = sched.add_notebook(nb, policy="cost", use_knowledge=False)
    sched.inject_failure("gpu", at=5.0, recover_after=10.0)
    rep = sched.run()
    assert rep.recoveries >= 1
    ns_total = (rt.envs["local"].state.get("total")
                or rt.envs["gpu"].state.get("total"))
    assert ns_total == 2                  # not 3/4 from double-appends


def test_provisioning_env_waits_for_cold_start():
    """Placement may target a provisioning env, but execution must not
    start before ready_at — the wait is charged as queue time."""
    from repro.core import HybridRuntime
    reg = EnvironmentRegistry()
    reg.register(ExecutionEnvironment("local"), home=True)
    cold = ExecutionEnvironment("cold-gpu", speedup=10.0,
                                status="provisioning", cold_start=25.0)
    cold.ready_at = 25.0
    reg.register(cold)
    nb = Notebook("cold")
    nb.add_cell("x = 1", cost=1.0)
    rt = HybridRuntime(nb, registry=reg, use_knowledge=False)
    rt.run_cell(0, force_env="cold-gpu")
    assert rt.clock.now() >= 25.0
    assert rt.queue_wait > 0.0
    rt.close()


def test_stale_mark_up_event_respects_new_ready_at():
    """A provision cycle interrupted by a failure must not come up at the
    old ready_at — only the re-provision's own cold start counts."""
    from repro.core import EventLoop
    sched = SessionScheduler(make_reg(burst=True))   # burst cold_start=5
    loop = sched._loop = EventLoop()
    sched._set_status("burst", "provisioning", 10.0)       # ready_at 15
    loop.call_at(15.0, sched._mark_up, "burst")
    loop.call_at(12.0, sched._fail_env, "burst", 12.0, 1.0)  # reprovision @13
    loop.run()
    ups = [(t, e) for t, e, _o, new in sched.registry.lifecycle_log
           if new == "up" and e == "burst"]
    assert ups == [(18.0, "burst")]       # 13 + cold_start, not the stale 15


def test_detection_delay_follows_heartbeat_protocol():
    sched = SessionScheduler(make_reg(), beat_interval=2.0, miss_threshold=4)
    assert sched.detect_delay == 8.0
    coord = Coordinator(["a"], beat_interval=2.0, miss_threshold=4)
    assert coord.detection_delay == 8.0


# ----------------------------------------------------------------------
# autoscaling
# ----------------------------------------------------------------------

def test_autoscale_provisions_and_culls_burst_env():
    sched = SessionScheduler(make_reg(burst=True))
    sched.enable_autoscale(AutoscalePolicy(["burst"], check_interval=3.0,
                                           scale_up_wait=1.0))
    for i in range(4):
        sched.add_notebook(make_nb(f"-{i}"), policy="cost",
                           use_knowledge=False)
    sched.set_workload(WorkloadTrace.poisson(
        4, rate=0.2, think_mean=2.0, cells_per_session=4, seed=5))
    rep = sched.run()
    actions = [a for _, a, _ in rep.scale_events]
    assert "provision" in actions
    assert "cull" in actions              # idle_timeout reclaimed it
    assert rep.actual_env_seconds.get("burst", 0.0) > 0.0


def test_autoscale_reduces_queue_wait_vs_static():
    def run(burst):
        sched = SessionScheduler(make_reg(burst=burst))
        if burst:
            sched.enable_autoscale(AutoscalePolicy(
                ["burst"], check_interval=3.0, scale_up_wait=1.0))
        for i in range(4):
            sched.add_notebook(make_nb(f"-{i}"), policy="cost",
                               use_knowledge=False)
        sched.set_workload(WorkloadTrace.poisson(
            4, rate=0.2, think_mean=2.0, cells_per_session=4, seed=5))
        return sched.run()

    assert run(True).total_queue_wait < run(False).total_queue_wait


# ----------------------------------------------------------------------
# determinism (acceptance: same trace + seed => identical ScheduleReport)
# ----------------------------------------------------------------------

def test_seeded_fleet_runs_are_deterministic():
    def run_once():
        sched = SessionScheduler(make_reg(burst=True))
        sched.enable_recovery("checkpoint", interval=5.0)
        sched.enable_autoscale(AutoscalePolicy(["burst"]))
        for i in range(3):
            sched.add_notebook(make_nb(f"-{i}"), policy="cost",
                               use_knowledge=False)
        sched.set_workload(WorkloadTrace.poisson(
            3, rate=0.15, think_mean=3.0, cells_per_session=4, seed=99))
        sched.inject_failure("gpu", at=8.0, recover_after=12.0)
        return sched.run()

    assert run_once() == run_once()


# ----------------------------------------------------------------------
# satellite: run() must close every session even when a cell raises
# ----------------------------------------------------------------------

def test_run_closes_sessions_when_a_cell_raises():
    sched = SessionScheduler(make_reg())
    good = sched.add_notebook(make_nb("-ok"), policy="cost",
                              use_knowledge=False, pipeline=True)
    bad_nb = Notebook("bad")
    bad_nb.add_cell("x = 1", cost=0.1)
    bad_nb.add_cell("raise RuntimeError('boom')", cost=0.1)
    bad = sched.add_notebook(bad_nb, policy="cost", use_knowledge=False,
                             pipeline=True)
    with pytest.raises(RuntimeError, match="boom"):
        sched.run()
    # every runtime closed: bus subscribers detached, speculations cancelled
    for rt in (good, bad):
        assert rt.bus.subscriber_count("telemetry") == 0
        assert not rt.engine._pending


# ----------------------------------------------------------------------
# satellite: arbiter interval pruning
# ----------------------------------------------------------------------

def test_arbiter_prune_preserves_admission_decisions():
    def replay(prune):
        reg = make_reg()
        arb = CapacityArbiter(reg)
        starts = []
        now = 0.0
        for i in range(200):
            start = arb.acquire("gpu", now, 1.0)
            arb.release("gpu", start, start + 1.0)
            starts.append(start)
            now = start + 0.25
            if prune and i % 16 == 0:
                arb.prune(now)
        return starts, arb

    plain, _ = replay(False)
    pruned, arb = replay(True)
    assert plain == pruned                 # same admissions, fewer intervals
    assert arb.pruned_intervals > 0
    assert sum(len(v) for v in arb._busy.values()) < 200


def test_arbiter_prune_never_drops_live_intervals():
    reg = make_reg()
    arb = CapacityArbiter(reg)
    arb.release("gpu", 0.0, 10.0)
    arb.release("gpu", 5.0, 20.0)
    arb.prune(10.0)                       # [0,10] ends at the bound: droppable
    assert arb._busy["gpu"] == [(5.0, 20.0)]
    # the surviving interval still gates admission (capacity 1)
    assert arb.acquire("gpu", 12.0, 1.0) == 20.0


def test_expected_wait_peeks_without_recording_queue_events():
    reg = make_reg()
    arb = CapacityArbiter(reg)
    arb.release("gpu", 0.0, 10.0)
    assert arb.expected_wait("gpu", 2.0) == 8.0
    assert arb.queue_events == []
    assert arb.acquire("gpu", 2.0) == 10.0
    assert len(arb.queue_events) == 1
