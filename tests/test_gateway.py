"""GatewayService: warm-pool attach, fair-share admission, quotas,
detach-at-any-time, wire frontend, and the indexed-hot-path invariants."""
import pytest

from repro.core import wire
from repro.core.fabric import EnvironmentRegistry, ExecutionEnvironment
from repro.core.gateway import (
    GatewayService, percentile, poisson_attach_storm,
)
from repro.core.notebook import Notebook
from repro.core.transport import LoopbackTransport


def _registry(gpu_capacity=8):
    reg = EnvironmentRegistry()
    reg.register(ExecutionEnvironment("local"), home=True, capacity=1024)
    reg.register(ExecutionEnvironment("gpu", speedup=8.0),
                 capacity=gpu_capacity)
    reg.connect("local", "gpu", bandwidth=1e9, latency=0.05)
    return reg


def _nb(i=0):
    nb = Notebook(f"nb{i}")
    nb.add_cell("x = 2.0", cost=0.5)
    nb.add_cell("y = x * 3.0", cost=30.0)
    nb.add_cell("z = y + 1.0", cost=1.0)
    return nb


def _gateway(**kw):
    kw.setdefault("policy", "cost")
    kw.setdefault("use_knowledge", False)
    return GatewayService(_registry(), **kw)


# ----------------------------------------------------------------------
# attach / detach lifecycle
# ----------------------------------------------------------------------

def test_sessions_attach_and_complete():
    gw = _gateway(warm_pool=4)
    sids = [gw.attach(_nb(i), think=[1.0, 1.0, 1.0]) for i in range(6)]
    rep = gw.run()
    assert rep.sessions == 6 and rep.completed == 6 and rep.errors == 0
    assert {r.session for r in rep.session_reports} == set(sids)
    assert all(r.cells_run == 3 for r in rep.session_reports)


def test_attach_during_run_is_admitted():
    """A session attached from inside the event loop (while others run)
    is admitted and completes — the gateway is a service, not a batch."""
    gw = _gateway(warm_pool=2)
    gw.attach(_nb(0), think=[5.0, 5.0, 5.0])
    late = []
    gw.loop.call_at(7.0, lambda: late.append(
        gw.attach(_nb(1), think=[1.0, 1.0, 1.0])))
    rep = gw.run()
    assert rep.sessions == 2 and rep.completed == 2
    late_rep = [r for r in rep.session_reports if r.session == late[0]][0]
    assert late_rep.cells_run == 3


def test_detach_mid_session_frees_slot_and_records_partial():
    gw = _gateway(warm_pool=2)
    sid = gw.attach(_nb(0), think=[100.0, 100.0, 100.0])
    gw.loop.call_at(50.0, gw.detach, sid)
    rep = gw.run()
    assert rep.client_detached == 1
    (r,) = rep.session_reports
    assert 0 < r.cells_run < 3 and r.reason == "client"


def test_detach_unknown_session_raises_keyerror():
    gw = _gateway()
    with pytest.raises(KeyError):
        gw.detach("ghost")


def test_failing_cell_detaches_with_error_not_crash():
    gw = _gateway(warm_pool=1)
    nb = Notebook("bad")
    nb.add_cell("x = 1", cost=0.1)
    nb.add_cell("boom()", cost=0.1)
    gw.attach(nb)
    gw.attach(_nb(1))                   # the healthy neighbour
    rep = gw.run()
    assert rep.errors == 1 and rep.completed == 1
    bad = [r for r in rep.session_reports if r.notebook == "bad"][0]
    assert bad.reason == "error:NameError" and bad.cells_run == 1


# ----------------------------------------------------------------------
# warm pool
# ----------------------------------------------------------------------

def test_warm_attach_skips_cold_start_and_cold_attach_pays_it():
    cold = 8.0
    # pool of 2: first two attaches are warm, third (same instant) is cold
    gw = _gateway(warm_pool=2, cold_start=cold)
    for i in range(3):
        gw.attach(_nb(i))
    rep = gw.run()
    assert rep.sessions == 3
    assert gw.pool.hits == 2 and gw.pool.misses == 1
    assert rep.warm_attach_p99 == 0.0
    assert rep.cold_attach_p99 == pytest.approx(cold)


def test_pool_refills_in_background():
    gw = _gateway(warm_pool=2, cold_start=5.0)
    # rate far below K/cold_start: every attach after the initial pair
    # still finds a refilled worker
    for i in range(6):
        gw.attach(_nb(i), at=i * 10.0)
    rep = gw.run()
    assert gw.pool.misses == 0 and gw.pool.hits == 6
    assert gw.pool.refills >= 4
    assert rep.cold_attach_p99 == 0.0


def test_cold_provision_walks_the_lifecycle_audit_log():
    gw = _gateway(warm_pool=0, cold_start=5.0)
    gw.attach(_nb(0))
    rep = gw.run()
    assert rep.sessions == 1 and gw.pool.misses == 1
    (r,) = gw.reports
    assert r.attach_wait == pytest.approx(5.0)
    # the worker registry left with the session; check the lifecycle
    # audit trail (up -> down -> provisioning -> up) via a fresh acquire
    worker, delay = gw.pool.acquire(gw.loop.now())
    assert delay == 5.0 and not worker.warm
    log = worker.registry.lifecycle_log
    states = [(env, to) for _t, env, _old, to in log]
    assert ("gpu", "down") in states and ("gpu", "provisioning") in states


def test_warm_pool_zero_disables_pooling():
    gw = _gateway(warm_pool=0, cold_start=3.0)
    for i in range(3):
        gw.attach(_nb(i))
    gw.run()
    assert gw.pool.hits == 0 and gw.pool.misses == 3
    assert all(w == pytest.approx(3.0) for w in gw.cold_waits)


# ----------------------------------------------------------------------
# fair share + quotas
# ----------------------------------------------------------------------

def test_tenant_quota_bounds_concurrency():
    gw = _gateway(warm_pool=8)
    gw.add_tenant("capped", quota=2)
    for i in range(6):
        gw.attach(_nb(i), tenant="capped", think=[1.0, 1.0, 1.0])
    concurrency = []
    gw.loop.every(5.0, lambda: concurrency.append(
        gw.tenants["capped"].admitted))
    rep = gw.run(until=500.0)
    assert rep.sessions == 6 and rep.completed == 6
    assert max(concurrency) <= 2
    # the queue drained through the quota: later sessions waited
    assert gw.tenants["capped"].admission_wait > 0


def test_max_sessions_caps_the_whole_gateway():
    gw = _gateway(warm_pool=8, max_sessions=3)
    for i in range(9):
        gw.attach(_nb(i), think=[1.0, 1.0, 1.0])
    rep = gw.run()
    assert rep.sessions == 9 and rep.completed == 9
    assert rep.peak_concurrent <= 3


def test_drr_divides_admission_by_weight():
    """Under a shared max_sessions bottleneck, a weight-2 tenant gets
    sessions admitted ~2x as fast as a weight-1 tenant."""
    gw = _gateway(warm_pool=16, max_sessions=3)
    gw.add_tenant("heavy", weight=2.0)
    gw.add_tenant("light", weight=1.0)
    for i in range(12):
        gw.attach(_nb(i), tenant="heavy", think=[1.0])
        gw.attach(_nb(i), tenant="light", think=[1.0])
    rep = gw.run()
    assert rep.sessions == 24 and rep.completed == 24
    # heavy's sessions spent measurably less time queued in total
    heavy = gw.tenants["heavy"].admission_wait
    light = gw.tenants["light"].admission_wait
    assert heavy < light
    assert light / max(heavy, 1e-9) > 1.3


def test_unknown_tenant_is_autoregistered_with_defaults():
    gw = _gateway(warm_pool=2)
    gw.attach(_nb(0), tenant="walk-in")
    rep = gw.run()
    assert rep.completed == 1
    assert gw.tenants["walk-in"].quota is None


def test_add_tenant_validates_inputs():
    gw = _gateway()
    with pytest.raises(ValueError):
        gw.add_tenant("bad", weight=0.0)
    with pytest.raises(ValueError):
        gw.add_tenant("bad", quota=0)


# ----------------------------------------------------------------------
# indexed hot paths
# ----------------------------------------------------------------------

def test_wake_heap_prunes_arbiter_without_scanning_sessions():
    gw = _gateway(warm_pool=8, prune_interval=5.0)
    for i in range(20):
        gw.attach(_nb(i), at=i * 2.0, think=[10.0, 10.0, 10.0])
    rep = gw.run()
    assert rep.completed == 20
    # intervals were actually pruned during the run (not just at the end)
    assert rep.pruned_intervals > 0
    # the lazy heap fully drained its stale entries
    assert all(e[2].detached for e in gw._wake_heap)


def test_session_clock_gap_absorbs_into_think_time():
    gw = _gateway(warm_pool=2)
    gw.attach(_nb(0), think=[7.0, 3.0])
    rep = gw.run()
    (r,) = rep.session_reports
    assert rep.completed == 1
    # makespan covers cells + think gaps
    assert r.makespan >= 10.0


def test_percentile_is_nearest_rank():
    assert percentile([], 99) == 0.0
    assert percentile([5.0], 50) == 5.0
    xs = list(range(1, 101))
    assert percentile(xs, 50) == 50
    assert percentile(xs, 99) == 99
    assert percentile(xs, 100) == 100


# ----------------------------------------------------------------------
# wire frontend
# ----------------------------------------------------------------------

def test_wire_storm_end_to_end():
    gw = _gateway(warm_pool=4, cold_start=2.0)
    client, server = LoopbackTransport.pair()
    gw.add_frontend(server)
    sids = poisson_attach_storm(gw, n_sessions=10, rate=10.0,
                                think_mean=5.0, make_notebook=_nb, seed=7,
                                client=client)
    rep = gw.run()
    assert rep.sessions == 10 and rep.completed == 10
    assert {r.session for r in rep.session_reports} == set(sids)
    acks = completes = 0
    while (f := client.poll()) is not None:
        if f.ftype == wire.ACK:
            acks += 1
        elif f.ftype == wire.DETACH:
            assert wire.parse_detach(f)[1] == "complete"
            completes += 1
    assert acks == 20 and completes == 10   # queued-ack + attached-ack each


def test_wire_detach_mid_session():
    gw = _gateway(warm_pool=2, cold_start=1.0)
    client, server = LoopbackTransport.pair()
    gw.add_frontend(server)
    gw.expect_storm(1)
    nb = _nb(0)
    gw.loop.call_at(0.0, client.send, wire.attach_frame(
        "default", nb.name,
        [{"source": c.source, "cost": c.cost} for c in nb.cells],
        think=[1000.0], session="s-long"))
    gw.loop.call_at(10.0, client.send, wire.detach_frame("s-long"))
    rep = gw.run()
    assert rep.client_detached == 1
    assert rep.session_reports[0].cells_run == 1


def test_wire_detach_unknown_session_gets_error_frame():
    gw = _gateway(warm_pool=0)
    client, server = LoopbackTransport.pair()
    gw.add_frontend(server)
    gw.expect_storm(0)
    client.send(wire.detach_frame("ghost"))
    gw.run(until=1.0)
    seen = []
    while (f := client.poll()) is not None:
        seen.append(f.ftype)
    assert wire.ERROR in seen


def test_frontend_rejects_noncontrol_frames():
    gw = _gateway(warm_pool=0)
    client, server = LoopbackTransport.pair()
    gw.add_frontend(server)
    gw.expect_storm(0)
    client.send(wire.json_frame(wire.EXEC, {"source": "x = 1"}))
    gw.run(until=1.0)
    kinds = []
    while (f := client.poll()) is not None:
        kinds.append(f.ftype)
    assert kinds == [wire.ERROR]


def test_duplicate_session_id_is_uniquified_not_clobbered():
    gw = _gateway(warm_pool=4)
    gw.attach(_nb(0), session="dup", think=[5.0])
    gw.attach(_nb(1), session="dup", think=[5.0])
    rep = gw.run()
    assert rep.sessions == 2 and rep.completed == 2
    ids = {r.session for r in rep.session_reports}
    assert len(ids) == 2 and "dup" in ids
