"""Gateway control-plane wire format: ATTACH/DETACH/STREAM golden
vectors, corruption properties, and decoder memory bounds.

The golden stream pins the v1 encoding of the gateway frames the same way
``wire_v1_golden.bin`` pins the migration frames: any byte drift is a wire
break and must bump ``wire.VERSION``.
"""
import os

import pytest

from repro.core import wire
from repro.core.wire import Frame, FrameDecoder, WireError
from tests._hyp_compat import given, settings, st

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "wire_gateway_golden.bin")

# the canonical v1 DETACH (session=s-0001, reason=complete): canonical
# JSON means a semantic re-encode is byte-identical
GOLDEN_DETACH_HEX = ("280000000e7b22726561736f6e223a22636f6d706c657465222c"
                     "2273657373696f6e223a22732d30303031227d3ca3eb6f")


def _golden_frames():
    return [
        wire.attach_frame("alice", "nb0",
                          [{"source": "x = 1", "cost": 0.5},
                           {"source": "y = x * 2", "cost": 30.0}],
                          think=[1.5, 0.25], session="s-0001"),
        wire.json_frame(wire.ACK, {"queued": "s-0001"}),
        wire.stream_frame(5, wire.json_frame(
            wire.ACK, {"session": "s-0001", "warm": True})),
        wire.stream_frame(6, wire.detach_frame("s-0001", "client")),
        wire.detach_frame("s-0001", "complete"),
    ]


# ----------------------------------------------------------------------
# golden vectors
# ----------------------------------------------------------------------

def test_golden_stream_decodes_and_reencodes_byte_identical():
    with open(GOLDEN, "rb") as f:
        data = f.read()
    frames = wire.decode_frames(data)
    assert [f.ftype for f in frames] == [
        wire.ATTACH, wire.ACK, wire.STREAM, wire.STREAM, wire.DETACH]
    assert b"".join(f.encoded() for f in frames) == data
    assert frames[4].encoded().hex() == GOLDEN_DETACH_HEX


def test_golden_stream_matches_fresh_encoding():
    """The committed bytes are exactly what today's encoders emit —
    catches accidental format drift in either direction."""
    with open(GOLDEN, "rb") as f:
        data = f.read()
    assert b"".join(f.encoded() for f in _golden_frames()) == data


def test_golden_attach_parses_and_reencodes_identically():
    with open(GOLDEN, "rb") as f:
        frames = wire.decode_frames(f.read())
    doc = wire.parse_attach(frames[0])
    assert doc["tenant"] == "alice" and doc["notebook"] == "nb0"
    assert doc["cells"][1] == {"source": "y = x * 2", "cost": 30.0}
    assert doc["think"] == [1.5, 0.25] and doc["session"] == "s-0001"
    again = wire.attach_frame(doc["tenant"], doc["notebook"], doc["cells"],
                              think=doc["think"], session=doc["session"])
    assert again.encoded() == frames[0].encoded()


def test_golden_stream_envelopes_unwrap_to_inner_frames():
    with open(GOLDEN, "rb") as f:
        frames = wire.decode_frames(f.read())
    sid, inner = wire.parse_stream(frames[2])
    assert sid == 5 and inner.ftype == wire.ACK
    sid, inner = wire.parse_stream(frames[3])
    assert sid == 6 and inner.ftype == wire.DETACH
    assert wire.parse_detach(inner) == ("s-0001", "client")
    # the unwrapped inner frame re-encodes byte-identically
    assert inner.encoded() == wire.detach_frame("s-0001", "client").encoded()


def test_existing_v1_golden_still_decodes():
    """Adding gateway frame types must not disturb the original stream."""
    old = os.path.join(os.path.dirname(__file__), "data",
                       "wire_v1_golden.bin")
    with open(old, "rb") as f:
        frames = wire.decode_frames(f.read())
    assert frames[0].ftype == wire.HELLO
    assert wire.parse_hello(frames[0])["version"] == wire.VERSION


# ----------------------------------------------------------------------
# parse validation
# ----------------------------------------------------------------------

def test_parse_attach_rejects_malformed_documents():
    for bad in ({"notebook": "nb"},                       # missing tenant
                {"tenant": "t"},                          # missing notebook
                {"tenant": "t", "notebook": "nb",
                 "cells": [{"cost": 1.0}]},               # cell missing source
                {"tenant": "t", "notebook": "nb",
                 "cells": [{"source": "x", "cost": "free"}]},  # bad cost
                {"tenant": "t", "notebook": "nb",
                 "cells": "nope"}):                       # cells not a list
        with pytest.raises(WireError):
            wire.parse_attach(wire.json_frame(wire.ATTACH, bad))
    with pytest.raises(WireError):
        wire.parse_attach(wire.hello_frame())             # wrong frame type


def test_parse_detach_rejects_malformed_documents():
    with pytest.raises(WireError):
        wire.parse_detach(wire.json_frame(wire.DETACH, {"reason": "x"}))
    with pytest.raises(WireError):
        wire.parse_detach(wire.hello_frame())


def test_stream_frame_validates_stream_id_range():
    inner = wire.json_frame(wire.ACK, {})
    for sid in (-1, 1 << 32):
        with pytest.raises((WireError, ValueError)):
            wire.stream_frame(sid, inner)


def test_parse_stream_rejects_corrupt_inner_frames():
    good = wire.stream_frame(9, wire.detach_frame("s", "client"))
    raw = bytearray(good.encoded())
    # flip a byte inside the inner payload: the envelope CRC catches it
    raw[15] ^= 0xFF
    with pytest.raises(WireError):
        wire.decode_frames(bytes(raw))
    # truncate the inner frame but fix up the envelope so only the
    # inner-frame validation can object
    payload = bytes(good.payload)[:-3]
    forged = Frame(wire.STREAM, payload)
    with pytest.raises(WireError):
        wire.parse_stream(wire.decode_frames(forged.encoded())[0])
    # a STREAM too short to hold even the inner header
    forged = Frame(wire.STREAM, payload[:6])
    with pytest.raises(WireError):
        wire.parse_stream(wire.decode_frames(forged.encoded())[0])


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 255))
def test_bitflip_anywhere_is_rejected_or_decodes_identically(pos, flip):
    """Property: a flipped byte either raises WireError or (flip == 0)
    decodes identically — never a *different* valid stream."""
    with open(GOLDEN, "rb") as f:
        data = bytearray(f.read())
    good = wire.decode_frames(bytes(data))
    pos %= len(data)
    data[pos] ^= flip
    try:
        got = wire.decode_frames(bytes(data))
    except WireError:
        return
    assert got == good


def test_truncation_is_a_clean_error():
    with open(GOLDEN, "rb") as f:
        data = f.read()
    for cut in (1, 9, len(data) // 2, len(data) - 1):
        with pytest.raises(WireError):
            wire.decode_frames(data[:cut])


# ----------------------------------------------------------------------
# decoder memory bound (satellite: retained bytes stay O(unconsumed))
# ----------------------------------------------------------------------

def test_decoder_retains_o_of_unconsumed_not_o_of_stream():
    """Feed a long stream in small slices: after each drain the decoder
    must hold only the unconsumed tail, no matter how many bytes have
    passed through.  (The old decoder kept every fed segment until a
    frame completed *and* never trimmed the consumed prefix of a big
    head segment.)"""
    frame = wire.json_frame(wire.ACK, {"k": "v" * 64}).encoded()
    stream = frame * 400
    dec = FrameDecoder()
    seen = 0
    cap = 2 * FrameDecoder._COMPACT_MIN + len(frame)
    for i in range(0, len(stream), 7):
        dec.feed(stream[i:i + 7])
        seen += sum(1 for _ in dec.frames())
        assert dec.retained_bytes <= cap, (i, dec.retained_bytes)
    assert seen == 400
    assert dec.pending_bytes == 0


def test_decoder_compacts_consumed_prefix_of_one_big_buffer():
    """One huge feed, drained frame by frame: the consumed prefix must be
    released instead of pinning the whole buffer via a memoryview."""
    frame = wire.json_frame(wire.ACK, {"k": "v" * 500}).encoded()
    dec = FrameDecoder()
    dec.feed(frame * 300)               # one ~150 KB buffer
    drained = sum(1 for _ in dec.frames())
    assert drained == 300
    assert dec.pending_bytes == 0
    assert dec.retained_bytes <= len(frame) + 2 * FrameDecoder._COMPACT_MIN


def test_decoder_partial_tail_is_exactly_what_remains():
    frame = wire.json_frame(wire.ACK, {"n": 1}).encoded()
    dec = FrameDecoder()
    dec.feed(frame + frame[:5])
    assert sum(1 for _ in dec.frames()) == 1
    assert dec.pending_bytes == 5
    dec.feed(frame[5:])
    assert sum(1 for _ in dec.frames()) == 1
    assert dec.pending_bytes == 0
