"""Interaction models (predictive decision-plane subsystem)."""
from _hyp_compat import given, settings, st

from repro.core.context import sequence_stats
from repro.core.interaction import (
    ConfidenceGate, EnsembleModel, FrequencyModel, MarkovModel, RecencyModel,
    make_model,
)


# ----------------------------------------------------------------------
# FrequencyModel: incremental Algorithm 1 == reference rescan, bit for bit
# ----------------------------------------------------------------------

def _legacy_predict(hist, cur):
    stats = sequence_stats(hist, cur)
    if not stats:
        return (cur,), 0.0, 0
    best, score = max(stats.items(), key=lambda kv: (kv[1], len(kv[0])))
    i = best.index(cur)
    return best[i:], score, len(stats)


@given(st.lists(st.integers(0, 7), min_size=1, max_size=50))
@settings(max_examples=150, deadline=None)
def test_frequency_stats_bit_identical_to_rescan(hist):
    m = FrequencyModel()
    seen = []
    for o in hist:
        m.observe("nb", o)
        seen.append(o)
        for cur in [None] + sorted(set(seen)) + [99]:
            ref = sequence_stats(seen, cur)
            got = m.stats("nb", cur)
            # values AND dict ordering must match: the legacy tie-breaking
            # in predict_block_scored depends on iteration order
            assert list(ref.items()) == list(got.items())


@given(st.lists(st.integers(0, 6), min_size=1, max_size=40))
@settings(max_examples=150, deadline=None)
def test_frequency_predict_bit_identical_to_rescan(hist):
    m = FrequencyModel()
    seen = []
    for o in hist:
        m.observe("nb", o)
        seen.append(o)
        for cur in sorted(set(seen)):
            assert m.predict_block_scored("nb", cur) == _legacy_predict(seen, cur)


@given(st.lists(st.integers(0, 9), min_size=2, max_size=60))
@settings(max_examples=150, deadline=None)
def test_frequency_subset_count_invariants(hist):
    """Subset-count invariants of Algorithm 1: scores normalize to 100, are
    positive, and a contiguous subsequence never scores below a sequence
    that contains it (its subtotal includes every container's count)."""
    m = FrequencyModel()
    for o in hist:
        m.observe("nb", o)
    stats = m.stats("nb")
    assert stats, "non-empty history must yield stats"
    assert abs(sum(stats.values()) - 100.0) < 1e-6
    assert all(v > 0 for v in stats.values())
    seqs = list(stats)
    for a in seqs:
        for b in seqs:
            if a != b and len(a) <= len(b):
                n, mlen = len(a), len(b)
                if any(b[i:i + n] == a for i in range(mlen - n + 1)):
                    assert stats[a] >= stats[b]


def test_frequency_per_notebook_isolation_and_reset():
    m = FrequencyModel()
    for o in (0, 1, 2, 0, 1, 2):
        m.observe("a", o)
    assert m.stats("a") and not m.stats("b")
    m.reset("a")
    assert not m.stats("a")


# ----------------------------------------------------------------------
# MarkovModel
# ----------------------------------------------------------------------

@given(st.lists(st.integers(0, 8), min_size=2, max_size=60))
@settings(max_examples=150, deadline=None)
def test_markov_distribution_normalizes(hist):
    m = MarkovModel(order=2)
    for o in hist:
        m.observe("nb", o)
    for cur in set(hist) | {42}:
        dist = m.distribution("nb", cur)
        assert dist, "seen vocabulary must always yield a distribution"
        assert abs(sum(dist.values()) - 1.0) < 1e-9
        assert all(p > 0 for p in dist.values())  # Laplace smoothing


def test_markov_uses_higher_order_context():
    # 0 -> 1 after 5, but 0 -> 2 after 7: order-2 disambiguates
    m = MarkovModel(order=2, alpha=0.1)
    for _ in range(5):
        for o in (5, 0, 1, 7, 0, 2):
            m.observe("nb", o)
    # tail ends ...,0,2 — simulate context (7, 0):
    m.observe("nb", 7)
    assert m.predict_next("nb", 0) == 2
    m.observe("nb", 0)
    m.observe("nb", 2)
    m.observe("nb", 5)
    assert m.predict_next("nb", 0) == 1


def test_markov_block_rollout():
    m = MarkovModel(order=1)
    for _ in range(6):
        for o in (0, 1, 2, 3):
            m.observe("nb", o)
    block, score, ncand = m.predict_block_scored("nb", 1)
    assert block[0] == 1 and 2 in block
    assert score > 50.0 and ncand >= 1


# ----------------------------------------------------------------------
# RecencyModel: drift does not fossilize
# ----------------------------------------------------------------------

def test_recency_adapts_to_drift():
    m = RecencyModel(decay=0.8)
    for _ in range(50):
        m.observe("nb", 0)
        m.observe("nb", 1)          # regime A: 0 -> 1
    for _ in range(6):
        m.observe("nb", 0)
        m.observe("nb", 2)          # regime B: 0 -> 2
    assert m.predict_next("nb", 0) == 2

    # an undecayed counter would still say 1 (50 vs 6 observations)
    counts = MarkovModel(order=1, alpha=0.0)
    for _ in range(50):
        counts.observe("nb", 0)
        counts.observe("nb", 1)
    for _ in range(6):
        counts.observe("nb", 0)
        counts.observe("nb", 2)
    assert counts.predict_next("nb", 0) == 1


def test_recency_distribution_normalizes():
    m = RecencyModel()
    for o in (0, 1, 0, 2, 0, 1):
        m.observe("nb", o)
    dist = m.distribution("nb", 0)
    assert abs(sum(dist.values()) - 1.0) < 1e-9
    assert set(dist) == {1, 2}


# ----------------------------------------------------------------------
# EnsembleModel
# ----------------------------------------------------------------------

def test_ensemble_reweights_toward_better_member():
    m = EnsembleModel()
    w0 = dict(zip((mm.name for mm in m.models), m.weights))
    # drifting trace: recency should gain weight over raw frequency
    for _ in range(30):
        for o in (0, 1, 2, 3):
            m.observe("nb", o)
    for _ in range(30):
        for o in (0, 3, 1, 2):
            m.observe("nb", o)
    w1 = dict(zip((mm.name for mm in m.models), m.weights))
    assert abs(sum(m.weights) - 1.0) < 1e-9
    assert w1["recency"] > w0["recency"]
    dist = m.distribution("nb", 0)
    assert abs(sum(dist.values()) - 1.0) < 1e-9


# ----------------------------------------------------------------------
# ConfidenceGate
# ----------------------------------------------------------------------

def test_gate_tightens_on_misses_and_relaxes_on_hits():
    g = ConfidenceGate(threshold=0.5)
    t0 = g.threshold
    for _ in range(30):
        g.observe(False)
    assert g.threshold > t0            # misses -> stricter admission
    t_miss = g.threshold
    for _ in range(60):
        g.observe(True)
    assert g.threshold < t_miss        # hits -> relaxed admission
    lo, hi = g.bounds
    assert lo <= g.threshold <= hi
    assert g.issued == 90 and g.hits == 60
    assert g.allow(0.99) and not g.allow(0.0)


def test_make_model_registry():
    assert make_model(None).name == "frequency"
    assert make_model("markov").name == "markov"
    inst = RecencyModel()
    assert make_model(inst) is inst
    try:
        make_model("nope")
    except ValueError:
        pass
    else:
        raise AssertionError("unknown model name must raise")


def test_block_rollout_stops_at_wraparound():
    """Blocks are non-decreasing runs (paper §II-B): on a loop trace the
    rollout must end at the loop restart instead of promising a wrapped
    block the runtime's plan bookkeeping would silently truncate."""
    for model in (MarkovModel(order=1), RecencyModel()):
        for _ in range(8):
            for o in (0, 1, 2, 3):
                model.observe("nb", o)
        block, _score, _n = model.predict_block_scored("nb", 3)
        assert block == (3,), model.name          # not (3, 0, 1, 2)
        block, _score, _n = model.predict_block_scored("nb", 1)
        assert block[0] == 1 and list(block) == sorted(block), model.name


def test_gate_recovers_after_latching_high():
    """The threshold only rises on issued outcomes; rejections must decay a
    latched-high threshold back toward its initial value, or a miss storm
    would disable speculation permanently."""
    g = ConfidenceGate(threshold=0.35)
    for _ in range(200):
        g.observe(False)                    # miss storm: latches high
    assert g.threshold > 0.9
    assert not g.allow(0.8)
    for _ in range(200):
        g.rejected()                        # nothing admitted -> decay
    assert abs(g.threshold - 0.35) < 0.01   # back to the baseline gate
    assert g.allow(0.8)
    assert g.rejections == 200
