"""Knowledge base: EWMA thresholds, bounded provenance export, predictions."""
import json

from repro.core import KnowledgeBase, ParamEstimate, ProvRecord


def test_param_update_overwrite_by_default():
    kb = KnowledgeBase()
    kb.seed("epochs", 50.0)
    kb.update("epochs", 7.0)
    kb.update("epochs", 9.0)
    assert kb.get("epochs").threshold == 9.0      # paper behaviour preserved
    assert kb.get("epochs").source == "learned"


def test_param_update_ewma_smoothing():
    kb = KnowledgeBase()
    kb.seed("epochs", 50.0, smoothing=0.5)
    kb.update("epochs", 10.0)     # first learned value replaces the prior
    assert kb.get("epochs").threshold == 10.0
    kb.update("epochs", 20.0)     # then updates blend: 0.5*20 + 0.5*10
    assert abs(kb.get("epochs").threshold - 15.0) < 1e-9
    kb.update("epochs", 15.0)
    assert abs(kb.get("epochs").threshold - 15.0) < 1e-9
    assert kb.get("epochs").history == [10.0, 15.0, 15.0]


def test_ewma_respects_valid_range():
    est = ParamEstimate("p", 5.0, valid_range=(1.0, 10.0), smoothing=0.9)
    est.update(100.0)             # clamped before and after blending
    assert est.threshold <= 10.0
    est.update(-50.0)
    assert est.threshold >= 1.0


def test_export_json_bounded_and_serializable():
    kb = KnowledgeBase()
    kb.seed("epochs", 50.0)
    for i in range(40):
        kb.record(ProvRecord("cell-run", f"c{i}", "local", float(i),
                             float(i) + 1.0,
                             params={"obj": object()}))   # non-JSON value
    out = json.loads(kb.export_json(max_records=5))
    assert out["exported_records"] == 5
    assert out["total_records"] == 40
    assert [r["cell_id"] for r in out["records"]] == \
        [f"c{i}" for i in range(35, 40)]                  # most recent last
    assert "epochs" in out["params"]
    assert out["params"]["epochs"]["threshold"] == 50.0


def test_export_json_kind_filter():
    kb = KnowledgeBase()
    kb.record(ProvRecord("cell-run", "c0", "local", 0.0, 1.0))
    kb.record(ProvRecord("migration", None, "remote", 1.0, 2.0))
    out = json.loads(kb.export_json(kind="migration"))
    assert len(out["records"]) == 1
    assert out["records"][0]["kind"] == "migration"


def test_record_prediction_provenance():
    kb = KnowledgeBase()
    rec = kb.record_prediction("c1", "nb", {2: 0.7, 3: 0.2, 4: 0.1},
                               realized=2, when=5.0)
    assert rec.kind == "prediction"
    assert rec.params["hit"] is True
    assert rec.params["prob_realized"] == 0.7
    assert rec.params["predicted"][0] == [2, 0.7]
    miss = kb.record_prediction("c2", "nb", {2: 0.7, 3: 0.3}, realized=3)
    assert miss.params["hit"] is False
    assert len(kb.records("prediction")) == 2


def test_export_json_zero_records():
    kb = KnowledgeBase()
    kb.record(ProvRecord("cell-run", "c0", "local", 0.0, 1.0))
    out = json.loads(kb.export_json(max_records=0))
    assert out["records"] == [] and out["exported_records"] == 0
    assert out["total_records"] == 1
