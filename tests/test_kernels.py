"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(7)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 256, 64),     # GQA 4:1
    (1, 8, 1, 128, 128),    # MQA
    (1, 6, 6, 192, 32),     # non-pow2 heads/seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, KV, S, hd, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    ref = attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_noncausal():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ----------------------------------------------------------------------
# SSD scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 128, 4, 16, 32, 32),
    (1, 256, 2, 64, 128, 64),
    (1, 64, 8, 32, 16, 64),   # single chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(B, S, H, P, N, Q, dtype):
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_ref
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)) - 1).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bi = jax.random.normal(ks[3], (B, S, N), dtype)
    Ci = jax.random.normal(ks[4], (B, S, N), dtype)
    y, stt = ssd_scan(x, dt, A, Bi, Ci, chunk=Q, interpret=True)
    yr, str_ = ssd_ref(x, dt, A, Bi, Ci, Q)
    tol = 5e-4 if dtype == jnp.float32 else 1.5e-1  # bf16 inputs: long-chunk
    # decay chains accumulate rounding in both impls (f32 internals)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(stt, np.float32),
                               np.asarray(str_, np.float32), atol=tol, rtol=tol)


# ----------------------------------------------------------------------
# RG-LRU scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W,bs,bw", [
    (2, 128, 64, 32, 32),
    (1, 256, 128, 64, 128),
    (3, 64, 32, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(B, S, W, bs, bw, dtype):
    from repro.kernels.rg_lru.ops import rglru_scan
    from repro.kernels.rg_lru.ref import rglru_ref
    ks = jax.random.split(KEY, 2)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.98).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, W)) * 0.1).astype(dtype)
    h, hl = rglru_scan(a, b, block_s=bs, block_w=bw, interpret=True)
    hr, hlr = rglru_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(h, np.float32), np.asarray(hr),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(hl, np.float32), np.asarray(hlr),
                               atol=tol, rtol=tol)


# ----------------------------------------------------------------------
# blockwise quant
# ----------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(33, 77), (1024,), (5, 5, 5), (3000,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_matches_ref(shape, dtype):
    from repro.kernels.quant_blockwise.ops import dequantize, quantize
    x = jax.random.normal(KEY, shape, dtype)
    qk, sk = quantize(x, interpret=True)
    qr, sr = quantize(x, impl="xla")
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    y = dequantize(qk, sk, shape, dtype, interpret=True)
    err = np.abs(np.asarray(y, np.float32) - np.asarray(x, np.float32))
    assert err.max() <= float(jnp.max(jnp.abs(x.astype(jnp.float32)))) / 127 + 1e-2


# ----------------------------------------------------------------------
# hash delta
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_hash_kernel_matches_ref(dtype):
    from repro.kernels.hash_delta.ops import tensor_digest
    if dtype == jnp.int32:
        x = jnp.arange(3000, dtype=dtype)
    else:
        x = jax.random.normal(KEY, (60, 50), dtype)
    hk = tensor_digest(x, interpret=True)
    hr = tensor_digest(x, impl="xla")
    assert int(hk) == int(hr)


def test_hash_sensitivity_and_order():
    from repro.kernels.hash_delta.ops import tensor_digest
    x = jax.random.normal(KEY, (128,), jnp.float32)
    h0 = int(tensor_digest(x, impl="xla"))
    assert int(tensor_digest(x + 1e-3, impl="xla")) != h0
    perm = jnp.concatenate([x[1:], x[:1]])
    assert int(tensor_digest(perm, impl="xla")) != h0  # position-sensitive
