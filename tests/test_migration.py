"""Migration engine + hybrid runtime (paper §II, Fig. 1/3)."""
import numpy as np

from repro.core import (
    ExecutionEnvironment, HybridRuntime, MigrationEngine, Notebook,
    StateReducer,
)
from repro.core import telemetry as T


def _seeded_envs():
    l = ExecutionEnvironment("local")
    r = ExecutionEnvironment("remote", speedup=8.0)
    l.execute("""
import numpy as np
data = np.arange(5000, dtype=np.float32)
factor = 3.0
def scalef(x):
    return x * factor
""")
    return l, r


def test_reduced_migration_excludes_unneeded():
    l, r = _seeded_envs()
    l.execute("junk = np.zeros((1000, 1000))")
    eng = MigrationEngine(StateReducer("zlib"))
    res = eng.migrate(l, r, "out = scalef(data)")
    assert "junk" not in res.names
    assert {"data", "factor", "scalef"} <= set(res.names)
    r.execute("out = scalef(data)")
    assert float(r.state["out"][1]) == 3.0


def test_delta_second_migration_empty():
    l, r = _seeded_envs()
    eng = MigrationEngine(StateReducer("zlib"))
    eng.migrate(l, r, "out = scalef(data)")
    res2 = eng.migrate(l, r, "out = scalef(data)")
    assert res2.names == () and res2.nbytes == 0


def test_delta_return_path_only_new_objects():
    l, r = _seeded_envs()
    eng = MigrationEngine(StateReducer("zlib"))
    eng.migrate(l, r, "out = scalef(data)")
    r.execute("out = scalef(data)")
    eng.invalidate("remote", {"out"})
    back = eng.migrate(r, l, None)   # full-state request, delta-filtered
    assert "out" in back.names       # new object moves
    assert "data" not in back.names  # unchanged object does not
    np.testing.assert_allclose(l.state["out"], l.state["data"] * 3.0)


def test_invalidated_name_resent_even_when_digest_matches():
    """A (re)defined name is stale on every peer: the next migration must
    re-send it even if the new binding hashes identically (regression for
    invalidate only clearing the executing env's own view)."""
    l, r = _seeded_envs()
    eng = MigrationEngine(StateReducer("zlib"))
    eng.migrate(l, r, "out = scalef(data)")
    assert "factor" in eng.synced["remote"]
    # redefine `factor` on local: same content, new binding
    l.execute("factor = 3.0")
    eng.invalidate("local", {"factor"})
    res = eng.migrate(l, r, "out = scalef(data)")
    assert "factor" in res.names          # re-sent on the next migration
    assert "data" in eng.synced["remote"]  # unrelated names stay synced


def test_noop_migration_free_and_uncounted():
    """An empty send+dead delta costs 0 seconds (no latency charge) and does
    not count as a migration at the runtime level."""
    l, r = _seeded_envs()
    eng = MigrationEngine(StateReducer("zlib"), latency=2.0, bandwidth=100.0)
    first = eng.migrate(l, r, "out = scalef(data)")
    assert not first.noop and first.seconds >= 2.0
    again = eng.migrate(l, r, "out = scalef(data)")
    assert again.noop and again.seconds == 0.0 and again.nbytes == 0

    nb, rt = _runtime()
    rt.run_cell(0)
    rt.run_cell(1, force_env="remote")      # out + return: 2 real migrations
    migs = rt.migrations
    assert migs == 2
    rt.run_cell(1, force_env="remote")
    # forward trip is an empty delta (xs unchanged): free and uncounted;
    # the return trip re-sends the redefined ys, so exactly one is added
    assert rt.migrations == migs + 1
    noops = [m for m in rt.engine.log if m.noop]
    assert noops and all(m.seconds == 0.0 for m in noops)


def test_module_alias_reimported():
    l, r = _seeded_envs()
    eng = MigrationEngine(StateReducer("zlib"))
    eng.migrate(l, r, "y = np.sum(data)")
    r.execute("y = np.sum(data)")
    assert float(r.state["y"]) == float(np.arange(5000, dtype=np.float32).sum())


def test_migration_time_model():
    eng = MigrationEngine(StateReducer("none"), bandwidth=100.0, latency=2.0)
    assert eng.transfer_seconds(500) == 2.0 + 5.0


def _runtime(policy="block", **kw):
    nb = Notebook("demo")
    nb.add_cell("import numpy as np\nxs = np.arange(100.0)", cost=0.1)
    nb.add_cell("ys = xs * 2", cost=0.2)
    nb.add_cell("z = float((ys ** 3).sum())", cost=30.0)
    nb.add_cell("w = z + 1", cost=0.1)
    rt = HybridRuntime(
        nb, envs={"local": ExecutionEnvironment("local"),
                  "remote": ExecutionEnvironment("remote", speedup=10.0)},
        policy=policy, use_knowledge=False, latency=0.5, bandwidth=1e8, **kw)
    return nb, rt


def test_runtime_learns_to_migrate():
    nb, rt = _runtime()
    for _ in range(3):
        for i in range(4):
            rt.run_cell(i)
    rt.close()
    local_only = 3 * (0.1 + 0.2 + 30.0 + 0.1)
    assert rt.clock.now() < local_only          # policy beat local-only
    assert rt.migrations > 0
    assert "z" in rt.envs["remote"].state.ns    # heavy cell ran remotely
    assert rt.current_env == "local"            # returned after block
    types = [m.type for m in rt.bus.messages()]
    assert types[0] == T.SESSION_STARTED and types[-1] == T.SESSION_DISPOSED
    assert T.CELL_EXECUTION_COMPLETED in types


def test_serialization_failure_falls_back_local():
    nb, rt = _runtime()
    nb.cells[2].source = "import threading\nlock = threading.Lock()\n" + \
        "z = float((ys ** 3).sum())"
    # force migration attempt of an unpicklable object on pass 2
    nb.cells[3].source = "w = z + (1 if lock else 0)"
    for _ in range(3):
        for i in range(4):
            rt.run_cell(i)
    # runtime must have survived; all state consistent locally
    assert "w" in rt.envs["local"].state.ns or "w" in rt.envs["remote"].state.ns


def test_forced_env_and_provenance():
    nb, rt = _runtime()
    rt.run_cell(0)
    rt.run_cell(1)
    rt.run_cell(2, force_env="remote")
    assert "z" in rt.envs["remote"].state.ns
    migs = rt.kb.records("migration")
    assert migs and migs[0].env == "remote"


# ----------------------------------------------------------------------
# confidence-gated speculative prefetch (decision plane over the pipeline)
# ----------------------------------------------------------------------

def _prefetch_pair():
    from repro.core import EnvironmentRegistry
    reg = EnvironmentRegistry(default_bandwidth=1e6, default_latency=1.0)
    l = reg.register(ExecutionEnvironment("local"), home=True)
    r = reg.register(ExecutionEnvironment("remote", speedup=10.0))
    l.execute("import numpy as np\n"
              "data = np.arange(250_000, dtype=np.float64)\n"
              "def use(x):\n    return float(x.sum())\n")
    return reg, l, r


def test_prefetch_gate_rejects_low_confidence():
    from repro.core import ConfidenceGate, PipelinedMigrationEngine
    reg, l, r = _prefetch_pair()
    eng = PipelinedMigrationEngine(StateReducer("none"), registry=reg,
                                   gate=ConfidenceGate(threshold=0.5))
    assert eng.begin_prefetch(l, r, "out = use(data)", now=0.0,
                              prob=0.2) is None
    assert eng.prefetch_gated == 1 and eng.prefetch_issued == 0
    # clearing the threshold admits the speculation
    p = eng.begin_prefetch(l, r, "out = use(data)", now=0.0, prob=0.9)
    assert p is not None and eng.prefetch_issued == 1
    # planned transfers (prob=None) always bypass the gate
    eng2 = PipelinedMigrationEngine(StateReducer("none"), registry=reg,
                                    gate=ConfidenceGate(threshold=0.99))
    assert eng2.begin_prefetch(l, r, "out = use(data)", now=0.0) is not None


def test_cancelled_prefetch_accounts_wasted_bytes():
    from repro.core import PipelinedMigrationEngine
    reg, l, r = _prefetch_pair()
    eng = PipelinedMigrationEngine(StateReducer("none"), registry=reg)
    p = eng.begin_prefetch(l, r, "out = use(data)", now=0.0, prob=0.9,
                           predicted_order=2)
    assert p is not None
    # cancel after the transfer fully completed: every byte was wasted
    stale = eng.cancel_stale(keep=set(), now=p.ready_at + 1.0)
    assert stale == [("remote", p.nbytes, 2)]
    assert eng.prefetch_cancelled == 1
    assert eng.prefetch_wasted_bytes == p.nbytes
    # the pending claim is gone: a later migrate pays synchronously...
    res = eng.migrate(l, r, "out = use(data)", now=p.ready_at + 1.0)
    assert res.prefetched == ()
    # ...but completed chunks were banked into the receiver's CAS, so the
    # wire bytes collapse to the manifest (waste is time, not a re-send)
    assert res.nbytes < p.nbytes / 10


def test_partial_cancel_wastes_only_delivered_fraction():
    from repro.core import PipelinedMigrationEngine
    reg, l, r = _prefetch_pair()
    eng = PipelinedMigrationEngine(StateReducer("none"), registry=reg)
    p = eng.begin_prefetch(l, r, "out = use(data)", now=0.0, prob=0.9)
    mid = p.started_at + (p.ready_at - p.started_at) / 2.0
    wasted = eng.cancel_prefetch("remote", now=mid)
    assert 0 < wasted < p.nbytes            # only what already streamed
    assert eng.prefetch_wasted_bytes == wasted


def test_stale_claim_sets_wasted_bytes_on_result():
    from repro.core import PipelinedMigrationEngine
    reg, l, r = _prefetch_pair()
    eng = PipelinedMigrationEngine(StateReducer("none"), registry=reg)
    p = eng.begin_prefetch(l, r, "out = use(data)", now=0.0, prob=0.9)
    # the overlapped cell redefines the array the speculation carried: its
    # bytes (nearly all of the snapshot) streamed for nothing
    l.execute("data = np.ones(10)")
    eng.invalidate("local", {"data"})
    res = eng.migrate(l, r, "out = use(data)", now=p.ready_at + 1.0)
    assert "data" in res.names and "data" not in res.prefetched
    assert res.wasted_prefetch_bytes > p.nbytes * 0.9
    assert eng.prefetch_wasted_bytes == res.wasted_prefetch_bytes


def test_superseded_speculation_cancelled_on_reissue():
    from repro.core import PipelinedMigrationEngine
    reg, l, r = _prefetch_pair()
    eng = PipelinedMigrationEngine(StateReducer("none"), registry=reg)
    p1 = eng.begin_prefetch(l, r, "out = use(data)", now=0.0, prob=0.9)
    l.execute("data = np.arange(9.0)")
    eng.invalidate("local", {"data"})
    p2 = eng.begin_prefetch(l, r, "out = use(data)", now=1.0, prob=0.9)
    assert p2 is not None and eng.prefetch_cancelled == 1
    assert eng.prefetch_wasted_bytes > 0        # p1's delivered fraction


def test_runtime_prediction_provenance_and_hit_rate():
    nb, rt = _runtime(pipeline=True)
    for _ in range(3):
        for i in range(4):
            rt.run_cell(i)
    rt.close()
    assert rt.prediction_total > 0
    assert 0.0 <= rt.prediction_hit_rate <= 1.0
    preds = rt.kb.records("prediction")
    assert preds
    p = preds[-1].params
    assert "predicted" in p and "realized" in p and "hit" in p
    # close() detached the context detector from the bus
    assert rt.bus.subscriber_count("telemetry") == 0


def test_block_migration_ships_whole_block_state():
    """Regression: committing to a block must move the state every in-block
    cell needs — later block cells run without migrating, so an input used
    only by a later cell (xs below) has to travel with the block commit."""
    nb = Notebook("block-state")
    nb.add_cell("import numpy as np\nxs = np.arange(100.0)", cost=0.1)
    nb.add_cell("ys = xs * 2", cost=0.2)
    nb.add_cell("z = float((ys ** 2).sum())", cost=40.0)
    nb.add_cell("m = z / xs.size", cost=25.0)   # needs xs, not just z
    nb.add_cell("out = m + 1", cost=0.1)
    rt = HybridRuntime(
        nb, envs={"local": ExecutionEnvironment("local"),
                  "remote": ExecutionEnvironment("remote", speedup=10.0)},
        policy="block", use_knowledge=False, latency=0.5, bandwidth=1e8)
    for _ in range(3):
        for i in range(len(nb.cells)):
            rt.run_cell(i)       # raised NameError('xs') before the fix
    rt.close()
    assert rt.migrations > 0
    assert rt.envs["local"].state["out"] == rt.envs["local"].state["m"] + 1


def test_close_cancels_inflight_speculations():
    """A session's final prefetch is never claimed: close() must cancel it
    so its bytes land in the waste accounting (and telemetry)."""
    from repro.core import PipelinedMigrationEngine
    nb, rt = _runtime(pipeline=True)
    for _ in range(2):
        for i in range(4):
            rt.run_cell(i)
    eng = rt.engine
    assert isinstance(eng, PipelinedMigrationEngine)
    # force a dangling speculation of never-synced state, let the transfer
    # stream for a while, then close mid-flight
    rt.envs["local"].execute("import numpy as _np\n"
                             "bulk = _np.arange(50_000, dtype=_np.float64)")
    p = eng.begin_prefetch(rt.envs["local"], rt.envs["remote"],
                           "q = float(bulk.sum())", now=rt.clock.now(),
                           prob=0.9)
    assert p is not None and p.nbytes > 0
    rt.clock.advance(p.ready_at - p.started_at)      # fully streamed
    wasted_before = eng.prefetch_wasted_bytes
    rt.close()
    assert eng._pending == {}
    assert eng.prefetch_wasted_bytes > wasted_before
    types = [m.type for m in rt.bus.messages()]
    assert T.STATE_PREFETCH_CANCELLED in types
    assert types[-1] == T.SESSION_DISPOSED
