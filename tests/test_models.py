"""Model-level correctness: decode-vs-forward equivalence, local attention,
RoPE, MoE determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM
from repro.models.attention import (
    banded_local_attention, full_causal_attention,
)

KEY = jax.random.PRNGKey(3)


def _decode_consistency(arch, **cfg_over):
    cfg = get_config(arch, reduced=True)
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    B, S = 2, 64
    lm = LM(cfg, max_seq=128)
    params = lm.init(KEY, dtype=jnp.float32)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    cache_len = S
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.full((B, cfg.num_patches, cfg.d_model),
                                          0.01, jnp.float32)
        cache_len = S + cfg.num_patches
    if cfg.family == "encdec":
        batch["encoder_frames"] = jnp.full((B, cfg.encoder_seq, cfg.d_model),
                                           0.01, jnp.float32)
    full, _, _ = lm.forward(params, batch)
    _, cache = lm.prefill(params, dict(batch, tokens=toks[:, :S - 1]),
                          cache_len=cache_len)
    dec, _ = lm.decode_step(params, cache, {"token": toks[:, S - 1:S]})
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("arch", ["yi-6b", "stablelm-12b", "mamba2-370m",
                                  "recurrentgemma-9b", "whisper-tiny",
                                  "internvl2-2b", "qwen3-moe-235b-a22b"])
def test_decode_matches_forward(arch):
    # MoE needs headroom so capacity drops are identical across paths
    over = {"capacity_factor": 8.0} if "moe" in arch else {}
    _decode_consistency(arch, **over)


def test_banded_equals_masked_full():
    B, S, H, KV, hd, W = 1, 128, 4, 2, 16, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    banded = banded_local_attention(q, k, v, window=W)
    # reference: full attention with an explicit window mask
    qg = q.reshape(B, S, KV, H // KV, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) * hd ** -0.5
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = (i >= j) & (i - j < W)
    s = jnp.where(mask[None, None, None], s, -1e30)
    ref = jnp.einsum("bkgqs,bskh->bqkgh",
                     jax.nn.softmax(s, -1), v).reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(ref), atol=1e-5)


def test_chunked_causal_equals_unchunked():
    B, S, H, hd = 1, 128, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    a = full_causal_attention(q, k, v, chunk_q=32)
    b = full_causal_attention(q, k, v, chunk_q=S)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_rope_relative_shift_invariance():
    from repro.models.layers import apply_rope
    hd, S = 32, 16
    x = jax.random.normal(KEY, (1, S, 2, hd), jnp.float32)
    p0 = jnp.arange(S)[None, :]
    r0 = apply_rope(x, p0)
    r7 = apply_rope(x, p0 + 7)
    # inner products between same relative offsets are preserved
    d0 = jnp.einsum("bshd,bthd->bhst", r0, r0)
    d7 = jnp.einsum("bshd,bthd->bhst", r7, r7)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d7), atol=1e-3)


def test_partial_rope_only_rotates_prefix():
    from repro.models.layers import apply_rope
    hd = 32
    x = jnp.ones((1, 4, hd), jnp.float32)
    out = apply_rope(x, jnp.arange(4)[None, :], rope_pct=0.25)
    rot = int(hd * 0.25)
    np.testing.assert_array_equal(np.asarray(out[..., rot:]),
                                  np.asarray(x[..., rot:]))
    assert not np.allclose(np.asarray(out[0, 1:, :rot]),
                           np.asarray(x[0, 1:, :rot]))


def test_moe_determinism_and_aux():
    from repro.models.moe import moe_ffn
    from repro.models.layers import init_params
    from repro.models.moe import moe_spec
    cfg = get_config("qwen3-moe-235b-a22b", reduced=True)
    p = init_params(moe_spec(cfg), KEY, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y1, a1 = moe_ffn(p, x, cfg)
    y2, a2 = moe_ffn(p, x, cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert float(a1) >= 0 and jnp.isfinite(a1)


def test_vocab_padding_never_predicted():
    cfg = get_config("minicpm-2b", reduced=True)  # odd vocab 503 -> padded 512
    lm = LM(cfg, max_seq=16)
    params = lm.init(KEY)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    loss, _ = lm.loss(params, {"tokens": toks})
    assert jnp.isfinite(loss)
    assert cfg.padded_vocab == 512 and cfg.vocab_size == 503
