"""Stream multiplexing: N migration sessions on ONE connection.

The load-bearing invariant: a session's per-stream byte counters must
equal the same traffic's counters on a dedicated connection exactly —
the mux envelope overhead lands on the shared transport's counters, never
on a session's.  That is what makes per-session accounting (and the
gateway bench's apples-to-apples comparison) honest.
"""
import socket
import threading

import pytest

from repro.core import wire
from repro.core.chunkstore import MemoryChunkStore
from repro.core.reducer import StateReducer
from repro.core.state import ExecutionState
from repro.core.transport import (
    LoopbackTransport, MigrationPeer, MuxEnvServer, MuxPeer, SocketTransport,
    WireReceiver,
)


def _ser(red, **ns):
    return red.serialize_names(ExecutionState(ns), list(ns))


def _socket_pair():
    srv = socket.create_server(("127.0.0.1", 0))
    port = srv.getsockname()[1]
    client = socket.create_connection(("127.0.0.1", port), timeout=5.0)
    conn, _ = srv.accept()
    srv.close()
    return SocketTransport(client), SocketTransport(conn)


def _mux_rig(n_streams, *, transport="loopback"):
    """Client MuxPeer with N MigrationPeers + a MuxEnvServer, all on one
    connection.  Returns (peers, per-stream (store, ns) map, server,
    shared client transport)."""
    if transport == "loopback":
        client_tr, server_tr = LoopbackTransport.pair()
    else:
        client_tr, server_tr = _socket_pair()
    red = StateReducer(codec="zlib")
    sides = {}

    def make_receiver(sid):
        store, ns = MemoryChunkStore(), {}
        sides[sid] = (store, ns)
        return WireReceiver(store, red, ns=ns)

    server = MuxEnvServer(server_tr, make_receiver, timeout=10.0)
    mux = MuxPeer(client_tr, initiator=True)
    peers = [MigrationPeer(mux.open_stream(), codec="zlib")
             for _ in range(n_streams)]
    return peers, sides, server, client_tr


@pytest.mark.parametrize("transport", ["loopback", "socket"])
def test_n_sessions_share_one_connection(transport):
    peers, sides, server, _ = _mux_rig(3, transport=transport)
    red = StateReducer(codec="zlib")
    for i, peer in enumerate(peers):
        peer.send_state(_ser(red, x=i, tag=f"s{i}"))
        peer.execute("y = x * 10")
    for peer in peers:
        peer.close()
    server.join()
    assert server.error is None
    assert server.streams_served == 3
    assert len(sides) == 3
    for _store, ns in sides.values():
        assert ns["y"] == ns["x"] * 10


def _serve_plain(receiver, transport):
    while True:
        frame = transport.recv(timeout=10.0)
        if frame.ftype == wire.BYE:
            return
        receiver.handle(frame, transport)


def test_per_stream_bytes_equal_dedicated_connection_bytes():
    """Run identical traffic through (a) a dedicated loopback per session
    and (b) mux streams on one shared loopback: every per-session frame
    and byte counter must match exactly.  (The exec RPC is excluded from
    the received-bytes comparison only because its RESULT frame embeds
    the remote wall-clock float, whose repr length varies run to run —
    its sent side is still compared byte-for-byte.)"""
    red = StateReducer(codec="zlib")

    def run_session(peer, i):
        peer.send_state(_ser(red, x=i, blob=bytes(range(256)) * 8))
        sent_before_exec = peer.transport.bytes_sent
        peer.execute("y = x + 1")
        exec_sent = peer.transport.bytes_sent - sent_before_exec
        peer.close()
        return exec_sent

    dedicated = []
    for i in range(3):
        ctr, str_ = LoopbackTransport.pair()
        rcv = WireReceiver(MemoryChunkStore(), red, ns={})
        t = threading.Thread(target=_serve_plain, args=(rcv, str_),
                             daemon=True)
        t.start()
        exec_sent = run_session(MigrationPeer(ctr, codec="zlib"), i)
        t.join(timeout=5.0)
        dedicated.append((ctr.frames_sent, ctr.bytes_sent,
                          ctr.frames_recv, exec_sent))

    peers, _sides, server, shared = _mux_rig(3)
    muxed = []
    for i, peer in enumerate(peers):
        exec_sent = run_session(peer, i)
        tr = peer.transport
        muxed.append((tr.frames_sent, tr.bytes_sent,
                      tr.frames_recv, exec_sent))
    server.join()
    assert server.error is None
    assert muxed == dedicated
    # the shared pipe carried everything plus the envelope overhead
    assert shared.bytes_sent > sum(d[1] for d in dedicated)


def test_interleaved_streams_do_not_cross_contaminate():
    """Frames from different sessions interleave on the shared pipe but
    land in the right namespaces."""
    peers, sides, server, _ = _mux_rig(4)
    red = StateReducer(codec="zlib")
    for i, peer in enumerate(peers):
        peer.send_state(_ser(red, x=100 + i))
    for i, peer in enumerate(peers):
        peer.execute(f"y = x - {i}")
    for peer in peers:
        peer.close()
    server.join()
    assert server.error is None
    # stream order == open order (ids 1,3,5,7), so y == 100 everywhere
    # only if each exec hit its own namespace
    got = sorted(ns["y"] for _store, ns in sides.values())
    assert got == [100, 100, 100, 100]
    xs = sorted(ns["x"] for _store, ns in sides.values())
    assert xs == [100, 101, 102, 103]


def test_stream_error_is_contained_to_its_stream():
    """A failing cell on one stream errors that session; its sibling on
    the same connection keeps working."""
    peers, _sides, server, _ = _mux_rig(2)
    red = StateReducer(codec="zlib")
    for i, peer in enumerate(peers):
        peer.send_state(_ser(red, x=i))
    with pytest.raises(RuntimeError):
        peers[0].execute("boom()")       # NameError on the remote
    assert peers[1].execute("y = x + 41") >= 0.0
    for peer in peers:
        peer.close()
    server.join()
    assert server.error is None


def test_per_stream_token_bucket_shapes_that_stream_only():
    """A rate-limited stream owns a private bucket; its sibling on the
    same connection has none, so the throttled stream's deficit can never
    delay the other."""
    client_tr, server_tr = LoopbackTransport.pair()
    mux = MuxPeer(client_tr, initiator=True)
    now = [0.0]
    slow = mux.open_stream(rate=1000.0, burst=100,
                           clock=lambda: now[0])
    fast = mux.open_stream()
    assert slow.bucket is not None and fast.bucket is None
    # bucket math is per-stream: a big frame over a 100-byte burst at
    # 1000 B/s must wait out its own deficit on the next send
    big = wire.json_frame(wire.ACK, {"pad": "z" * 400})
    first = slow.bucket.delay(big.wire_size)
    second = slow.bucket.delay(big.wire_size)
    assert second > first                # each send deepens the deficit
    assert second >= big.wire_size / 1000.0 * 0.5
    fast.send(big)
    fast.send(big)                       # sibling never waits
    server_mux = MuxPeer(server_tr, initiator=False)
    stream = server_mux.accept_stream(timeout=5.0)
    assert stream.sid == fast.sid
    assert stream.recv(timeout=5.0).ftype == wire.ACK


def test_poll_on_mux_stream_is_nonblocking():
    client_tr, server_tr = LoopbackTransport.pair()
    a = MuxPeer(client_tr, initiator=True)
    b = MuxPeer(server_tr, initiator=False)
    sa = a.open_stream()
    assert sa.poll() is None              # nothing pending: returns, no block
    sa.send(wire.json_frame(wire.ACK, {"n": 1}))
    sb = b.accept_stream(timeout=5.0)
    assert sb.sid == sa.sid
    assert wire.parse_json(sb.recv(timeout=5.0))["n"] == 1
    sb.send(wire.json_frame(wire.ACK, {"n": 2}))
    f = sa.poll()
    assert f is not None and wire.parse_json(f)["n"] == 2
    assert sa.poll() is None


def test_both_ends_can_open_streams_without_id_collision():
    client_tr, server_tr = LoopbackTransport.pair()
    a = MuxPeer(client_tr, initiator=True)
    b = MuxPeer(server_tr, initiator=False)
    a_ids = [a.open_stream().sid for _ in range(3)]
    b_ids = [b.open_stream().sid for _ in range(3)]
    assert a_ids == [1, 3, 5] and b_ids == [2, 4, 6]
    assert not set(a_ids) & set(b_ids)


def test_persistent_server_survives_stream_churn():
    """persistent=True keeps the connection serving after every open
    stream has said BYE — a gateway connection must outlive a drain."""
    client_tr, server_tr = LoopbackTransport.pair()
    red = StateReducer(codec="zlib")

    def make_receiver(sid):
        return WireReceiver(MemoryChunkStore(), red, ns={})

    server = MuxEnvServer(server_tr, make_receiver, timeout=10.0,
                          persistent=True)
    mux = MuxPeer(client_tr, initiator=True)
    for round_ in range(3):
        peer = MigrationPeer(mux.open_stream(), codec="zlib")
        peer.send_state(_ser(red, r=round_))
        peer.execute("rr = r * 2")
        peer.close()                      # BYE retires this stream only
    assert server.thread.is_alive()
    assert server.streams_served == 3
    client_tr.close()
    server.join()
