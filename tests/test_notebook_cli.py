"""The notebook-file runner (paper's tool as a CLI): ipynb in, decisions out."""
import json
import sys

import pytest

from repro.core.notebook import Notebook
from repro.launch.notebook import (
    build_registry, main, parse_env_spec, parse_fail_spec, parse_link_spec,
    run_notebook,
)


def _demo_ipynb(tmp_path):
    nb = {"nbformat": 4, "nbformat_minor": 5, "metadata": {"name": "t"},
          "cells": [
              {"id": "c0", "cell_type": "code",
               "metadata": {"repro": {"cost": 0.3}},
               "source": "import numpy as np\nxs = np.arange(1000.0)"},
              {"id": "c1", "cell_type": "markdown", "metadata": {},
               "source": "# only code cells are managed (paper §II-A)"},
              {"id": "c2", "cell_type": "code",
               "metadata": {"repro": {"cost": 15.0}},
               "source": "y = float((xs ** 2).sum())"},
              {"id": "c3", "cell_type": "code",
               "metadata": {"repro": {"cost": 0.1}},
               "source": "z = y + 1"},
          ]}
    p = tmp_path / "demo.ipynb"
    p.write_text(json.dumps(nb))
    return str(p)


def test_run_notebook_file(tmp_path):
    path = _demo_ipynb(tmp_path)
    report, nb = run_notebook(path, sessions=3, remote_speedup=10.0)
    assert report["speedup_vs_local"] > 1.2
    assert report["migrations"] >= 2
    assert report["decisions"]["c2"]  # heavy cell got an explained decision
    assert "c1" not in report["decisions"]  # markdown ignored
    # annotations survive the round-trip through the document format
    doc = nb.to_ipynb()
    nb2 = Notebook.from_ipynb(doc)
    heavy = nb2.cell("c2")
    assert heavy.annotations and heavy.cost == 15.0


def test_run_notebook_socket_transport_demo(tmp_path):
    """--transport socket: the remote env is a child Python process and
    migrations stream real wire frames; the report proves frames moved."""
    path = _demo_ipynb(tmp_path)
    report, _nb = run_notebook(path, sessions=2, transport="socket")
    assert report["transport"] == "socket"
    assert report["migrations"] >= 1
    # every migration is at least MANIFEST + END on the wire
    assert report["wire_frames"] >= 2 * report["migrations"]
    assert report["transfer_wall_seconds"] > 0
    # the heavy cell still lands remote and the session completes
    assert report["speedup_vs_local"] is None or \
        report["speedup_vs_local"] > 0


def test_socket_transport_rejects_fleet_mode(tmp_path):
    path = _demo_ipynb(tmp_path)
    with pytest.raises(ValueError, match="incompatible"):
        run_notebook(path, fleet=2, transport="socket")


def test_ipynb_roundtrip(tmp_path):
    path = _demo_ipynb(tmp_path)
    nb = Notebook.from_ipynb(json.loads(open(path).read()))
    doc = nb.to_ipynb()
    nb2 = Notebook.from_ipynb(doc)
    assert [c.cell_id for c in nb.cells] == [c.cell_id for c in nb2.cells]
    assert [c.source for c in nb.cells] == [c.source for c in nb2.cells]


def test_run_notebook_fleet_over_fabric(tmp_path):
    path = _demo_ipynb(tmp_path)
    report, _ = run_notebook(
        path, sessions=2, policy="cost", use_knowledge=False,
        extra_envs=["tpu-mesh:40:1"], links=["local:tpu-mesh:1e8:0.5"],
        fleet=3)
    assert report["fleet"] == 3
    assert len(report["per_session"]) == 3
    assert report["makespan"] > 0
    assert set(report["env_utilization"]) == {"local", "remote", "tpu-mesh"}


def test_run_notebook_pipelined_not_slower(tmp_path):
    path = _demo_ipynb(tmp_path)
    sync, _ = run_notebook(path, sessions=3, remote_speedup=10.0)
    pipe, _ = run_notebook(path, sessions=3, remote_speedup=10.0,
                           pipeline=True)
    assert pipe["modeled_seconds"] <= sync["modeled_seconds"]


def test_run_notebook_fleet_with_workload_and_recovery(tmp_path):
    path = _demo_ipynb(tmp_path)
    report, _ = run_notebook(
        path, sessions=2, policy="cost", use_knowledge=False, fleet=3,
        arrivals=0.1, think_time=2.0, seed=7,
        fail_envs=[("remote", 10.0, 20.0)], recovery="checkpoint",
        checkpoint_interval=5.0)
    assert report["failures"] == [("remote", 10.0)]
    assert report["recoveries"] >= 0
    assert report["total_think_time"] > 0.0
    assert any(s["arrival"] > 0.0 for s in report["per_session"])
    assert report["lifecycle_events"]


# ----------------------------------------------------------------------
# spec parsing: friendly errors, not bare tracebacks
# ----------------------------------------------------------------------

def test_parse_env_spec_accepts_full_form():
    assert parse_env_spec("tpu:40:2:down") == ("tpu", 40.0, 2, "down")
    assert parse_env_spec("gpu") == ("gpu", 1.0, 1, "up")


def test_parse_env_spec_rejects_malformed_numbers():
    with pytest.raises(ValueError, match="speedup 'fast' is not a number"):
        parse_env_spec("gpu:fast")
    with pytest.raises(ValueError, match="capacity 'two' is not an integer"):
        parse_env_spec("gpu:2:two")
    with pytest.raises(ValueError, match="must be 'up' or 'down'"):
        parse_env_spec("gpu:2:1:sideways")


def test_parse_link_spec_rejects_malformed_input():
    with pytest.raises(ValueError, match="expected a:b:bandwidth:latency"):
        parse_link_spec("a:b:1e9")
    with pytest.raises(ValueError, match="must be numbers"):
        parse_link_spec("a:b:fast:0.5")


def test_parse_fail_spec():
    assert parse_fail_spec("remote:30") == ("remote", 30.0, None)
    assert parse_fail_spec("remote:30:60") == ("remote", 30.0, 60.0)
    with pytest.raises(ValueError, match="expected env:time"):
        parse_fail_spec("remote")
    with pytest.raises(ValueError, match="must be numbers"):
        parse_fail_spec("remote:soon")


def test_build_registry_rejects_duplicate_env_names():
    with pytest.raises(ValueError, match="duplicate environment name"):
        build_registry(extra_envs=["remote:5"])
    with pytest.raises(ValueError, match="duplicate environment name"):
        build_registry(extra_envs=["tpu:40", "tpu:20"])


def test_main_reports_spec_errors_as_argparse_errors(tmp_path, capsys,
                                                     monkeypatch):
    path = _demo_ipynb(tmp_path)
    for bad in (["--env", "remote:5"], ["--env", "foo:abc"],
                ["--link", "a:b:xx:1"], ["--fail-env", "remote:soon"],
                ["--fail-env", "nosuch:5", "--fleet", "2"]):
        monkeypatch.setattr(sys, "argv", ["notebook", path] + bad)
        with pytest.raises(SystemExit) as exc:
            main()
        assert exc.value.code == 2        # argparse usage error, not a crash
        err = capsys.readouterr().err
        assert "error:" in err and "Traceback" not in err


def test_main_keeps_real_tracebacks_for_notebook_errors(tmp_path,
                                                        monkeypatch):
    """Only spec mistakes become argparse errors — a ValueError raised by
    the user's own notebook code must propagate as itself."""
    nb = {"nbformat": 4, "nbformat_minor": 5, "metadata": {"name": "boom"},
          "cells": [{"id": "c0", "cell_type": "code",
                     "metadata": {"repro": {"cost": 0.1}},
                     "source": "int('not-a-number')"}]}
    p = tmp_path / "boom.ipynb"
    p.write_text(json.dumps(nb))
    monkeypatch.setattr(sys, "argv", ["notebook", str(p)])
    with pytest.raises(ValueError, match="not-a-number"):
        main()


def test_parse_rate_spec():
    from repro.launch.notebook import parse_rate_spec
    assert parse_rate_spec("50MB/s") == pytest.approx(50e6)
    assert parse_rate_spec("2.5KB") == pytest.approx(2500.0)
    assert parse_rate_spec("1e6") == pytest.approx(1e6)
    assert parse_rate_spec("3GB/s") == pytest.approx(3e9)
    for bad in ("fast", "0MB/s", "-5KB", "", "MB/s"):
        with pytest.raises(ValueError):
            parse_rate_spec(bad)


def test_main_replication_flag_validation(tmp_path, capsys, monkeypatch):
    path = _demo_ipynb(tmp_path)
    for bad in (["--replicate"],                       # needs --fleet
                ["--trickle-rate", "10MB/s"],          # needs --replicate
                ["--fleet", "2", "--replicate", "--trickle-rate", "slow"],
                ["--fleet", "2", "--replicate", "--transport", "socket"]):
        monkeypatch.setattr(sys, "argv", ["notebook", path] + bad)
        with pytest.raises(SystemExit) as exc:
            main()
        assert exc.value.code == 2
        err = capsys.readouterr().err
        assert "error:" in err and "Traceback" not in err


def test_run_notebook_fleet_with_replication(tmp_path):
    path = _demo_ipynb(tmp_path)
    report, _ = run_notebook(path, sessions=2, policy="cost",
                             use_knowledge=False, fleet=2, replicate=True,
                             think_time=4.0)
    assert report["replicate"] is True
    assert report["trickled_bytes"] >= 0
    assert "trickle_claimed_bytes" in report
    assert "wasted_speculation_bytes" in report
    for s in report["per_session"]:
        assert "trickled_bytes" in s and "trickle_claimed_bytes" in s
