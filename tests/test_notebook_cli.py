"""The notebook-file runner (paper's tool as a CLI): ipynb in, decisions out."""
import json

from repro.core.notebook import Notebook
from repro.launch.notebook import run_notebook


def _demo_ipynb(tmp_path):
    nb = {"nbformat": 4, "nbformat_minor": 5, "metadata": {"name": "t"},
          "cells": [
              {"id": "c0", "cell_type": "code",
               "metadata": {"repro": {"cost": 0.3}},
               "source": "import numpy as np\nxs = np.arange(1000.0)"},
              {"id": "c1", "cell_type": "markdown", "metadata": {},
               "source": "# only code cells are managed (paper §II-A)"},
              {"id": "c2", "cell_type": "code",
               "metadata": {"repro": {"cost": 15.0}},
               "source": "y = float((xs ** 2).sum())"},
              {"id": "c3", "cell_type": "code",
               "metadata": {"repro": {"cost": 0.1}},
               "source": "z = y + 1"},
          ]}
    p = tmp_path / "demo.ipynb"
    p.write_text(json.dumps(nb))
    return str(p)


def test_run_notebook_file(tmp_path):
    path = _demo_ipynb(tmp_path)
    report, nb = run_notebook(path, sessions=3, remote_speedup=10.0)
    assert report["speedup_vs_local"] > 1.2
    assert report["migrations"] >= 2
    assert report["decisions"]["c2"]  # heavy cell got an explained decision
    assert "c1" not in report["decisions"]  # markdown ignored
    # annotations survive the round-trip through the document format
    doc = nb.to_ipynb()
    nb2 = Notebook.from_ipynb(doc)
    heavy = nb2.cell("c2")
    assert heavy.annotations and heavy.cost == 15.0


def test_ipynb_roundtrip(tmp_path):
    path = _demo_ipynb(tmp_path)
    nb = Notebook.from_ipynb(json.loads(open(path).read()))
    doc = nb.to_ipynb()
    nb2 = Notebook.from_ipynb(doc)
    assert [c.cell_id for c in nb.cells] == [c.cell_id for c in nb2.cells]
    assert [c.source for c in nb.cells] == [c.source for c in nb2.cells]


def test_run_notebook_fleet_over_fabric(tmp_path):
    path = _demo_ipynb(tmp_path)
    report, _ = run_notebook(
        path, sessions=2, policy="cost", use_knowledge=False,
        extra_envs=["tpu-mesh:40:1"], links=["local:tpu-mesh:1e8:0.5"],
        fleet=3)
    assert report["fleet"] == 3
    assert len(report["per_session"]) == 3
    assert report["makespan"] > 0
    assert set(report["env_utilization"]) == {"local", "remote", "tpu-mesh"}


def test_run_notebook_pipelined_not_slower(tmp_path):
    path = _demo_ipynb(tmp_path)
    sync, _ = run_notebook(path, sessions=3, remote_speedup=10.0)
    pipe, _ = run_notebook(path, sessions=3, remote_speedup=10.0,
                           pipeline=True)
    assert pipe["modeled_seconds"] <= sync["modeled_seconds"]
