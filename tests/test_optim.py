"""Optimizer + schedules."""
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig
from repro.optim import adamw_update, init_opt_state, make_schedule


def test_wsd_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100,
                     schedule="wsd", wsd_decay_frac=0.2)
    s = make_schedule(tc)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1e-3) < 1e-9            # warmup done
    assert abs(float(s(50)) - 1e-3) < 1e-9            # stable plateau
    assert float(s(100)) < float(s(85)) < float(s(80))  # decay tail


def test_cosine_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    s = make_schedule(tc)
    assert float(s(5)) < float(s(10))
    assert float(s(100)) < float(s(50)) < float(s(10))
    assert float(s(100)) >= 1e-4 * 0.99               # floor at 10%


def test_grad_clip_applied():
    tc = TrainConfig(grad_clip=1.0, weight_decay=0.0, learning_rate=1.0,
                     warmup_steps=0, total_steps=1)
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    opt = init_opt_state(params)
    huge = {"w": jnp.full((4,), 1e6, jnp.float32)}
    opt2, p2, m = adamw_update(tc, opt, huge, params)
    assert float(m["grad_norm"]) > 1e5
    assert np.all(np.isfinite(np.asarray(p2["w"], np.float32)))
    assert float(jnp.max(jnp.abs(p2["w"].astype(jnp.float32)))) < 10.0


def test_master_weights_fp32():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = init_opt_state(params)
    assert opt.master["w"].dtype == jnp.float32
    tc = TrainConfig(warmup_steps=0, total_steps=10)
    g = {"w": jnp.full((4,), 1e-3, jnp.float32)}
    opt2, p2, _ = adamw_update(tc, opt, g, params)
    assert p2["w"].dtype == jnp.bfloat16
    assert int(opt2.step) == 1
