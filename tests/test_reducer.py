"""State reducer: serialization codecs, deltas, digests (paper §II-D)."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hyp_compat import given, settings, st

from repro.core import ExecutionState, SerializationFailure, StateReducer
from repro.core.reducer import CODECS


def _roundtrip(objs, codec):
    red = StateReducer(codec=codec)
    st_ = ExecutionState(dict(objs))
    ser = red.serialize_names(st_, list(objs))
    return red.deserialize(ser), ser


@pytest.mark.parametrize("codec", CODECS)
def test_roundtrip_all_codecs(codec):
    objs = {
        "arr": np.arange(4000, dtype=np.float32).reshape(40, 100),
        "jarr": jnp.linspace(0, 1, 256, dtype=jnp.float32),
        "tree": {"a": np.ones(7), "b": [np.zeros(3), 5, "text"]},
        "scalar": 42,
        "string": "hello",
    }
    out, ser = _roundtrip(objs, codec)
    assert out["scalar"] == 42 and out["string"] == "hello"
    lossless = codec != "quant8+zstd"
    if lossless:
        np.testing.assert_array_equal(out["arr"], objs["arr"])
        np.testing.assert_array_equal(np.asarray(out["jarr"]), np.asarray(objs["jarr"]))
    else:
        # blockwise int8: relative error bounded by scale/127
        err = np.abs(out["arr"] - objs["arr"])
        bound = np.abs(objs["arr"]).max() / 127 + 1e-6
        assert err.max() <= bound
    assert ser.nbytes > 0


def test_compression_reduces_size():
    x = np.zeros((512, 512), np.float32)  # highly compressible
    _, raw = _roundtrip({"x": x}, "none")
    _, z = _roundtrip({"x": x}, "zlib")
    assert z.nbytes < raw.nbytes / 10


def test_function_roundtrip_rebinds_globals():
    src_ns = {}
    exec("scale = 3.0\ndef f(v):\n    return v * scale", src_ns)
    red = StateReducer(codec="zlib")
    ser = red.serialize_names(ExecutionState(src_ns), ["f", "scale"])
    target = {"scale": 100.0}
    out = red.deserialize(ser, target_ns=target)
    target.update(out)
    # migrated function must resolve `scale` in the *destination* namespace
    assert target["f"](2.0) == 2.0 * 3.0


def test_serialization_failure_raised():
    import threading
    red = StateReducer()
    with pytest.raises(SerializationFailure):
        red.serialize_names(ExecutionState({"bad": threading.Lock()}), ["bad"])


def test_on_error_skip_roundtrips_serializable_names():
    """on_error="skip": unserializable names stay behind, everything else
    round-trips intact (the return-migration path)."""
    import threading
    red = StateReducer(codec="zlib")
    objs = {"bad": threading.Lock(),
            "arr": np.arange(100, dtype=np.float32),
            "note": "still travels"}
    ser = red.serialize_names(ExecutionState(objs), list(objs),
                              on_error="skip")
    assert ser.skipped == ("bad",)
    assert set(ser.blobs) == {"arr", "note"}
    assert "bad" not in ser.digests          # skipped names have no digest
    out = red.deserialize(ser)
    np.testing.assert_array_equal(out["arr"], objs["arr"])
    assert out["note"] == "still travels"


def test_delta_names_semantics():
    red = StateReducer()
    s = ExecutionState({"a": np.arange(10), "b": np.zeros(5), "c": 1})
    send, dead, here = red.delta_names(s, {"a", "b", "c"}, known={})
    assert send == {"a", "b", "c"} and not dead
    known = dict(here)
    s["a"] = np.arange(10) + 1          # changed
    s.drop(["b"])                        # deleted
    send, dead, _ = red.delta_names(s, {"a", "c"}, known)
    assert send == {"a"}
    assert dead == {"b"}


@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_digest_deterministic_and_sensitive(vals):
    red = StateReducer()
    a = np.asarray(vals, np.float32)
    d1, d2 = red.digest(a), red.digest(a.copy())
    assert d1 == d2
    b = a.copy()
    b[0] = b[0] + 1.0 if np.isfinite(b[0] + 1.0) else 0.5
    if not np.array_equal(a, b):
        assert red.digest(b) != d1


def test_digest_keeps_all_64_bits_of_wide_dtypes():
    """With x64 disabled jnp.asarray narrows int64/float64; the digest must
    still see every bit or a high-word change silently skips migration."""
    red = StateReducer()
    a = np.array([2**32, 5], dtype=np.int64)
    b = np.array([2**33, 5], dtype=np.int64)       # differs above bit 32
    assert red.digest(a) != red.digest(b)
    f = np.array([1.0, 2.0], dtype=np.float64)
    g = f.copy()
    g[0] += 1e-9                                   # lost in a float32 cast
    assert red.digest(f) != red.digest(g)
    z = np.array([1 + 2j, 3 + 4j], dtype=np.complex128)
    w = z.copy()
    w[1] = 3 + 5j
    assert red.digest(z) != red.digest(w)


@given(st.integers(1, 3), st.integers(1, 2049))
@settings(max_examples=30, deadline=None)
def test_quant_roundtrip_bounds(seed, n):
    from repro.kernels.quant_blockwise.ops import dequantize, quantize
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    q, s = quantize(x, impl="xla")
    y = dequantize(q, s, (n,), jnp.float32, impl="xla")
    # per-block bound: |err| <= blockmax/127 (+eps)
    assert float(jnp.max(jnp.abs(y - x))) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_reduce_state_flag():
    ns = {}
    exec("import numpy as np\nbig = np.zeros((256,256))\nx = 1", ns)
    st_ = ExecutionState(ns)
    red_on = StateReducer(reduce_state=True)
    red_off = StateReducer(reduce_state=False)
    names_on, _, _ = red_on.reduce(st_, "y = x + 1")
    names_off, _, _ = red_off.reduce(st_, "y = x + 1")
    assert names_on == {"x"}
    assert "big" in names_off  # full state capture


def test_digest_handles_strided_complex128_views():
    """Non-contiguous wide leaves must digest by content, not by whatever
    bytes a raw view would alias."""
    red = StateReducer()
    base = np.arange(32, dtype=np.complex128) + 1j * np.arange(32)
    v = base[::2]                                   # strided view
    assert red.digest(v) == red.digest(np.ascontiguousarray(v))
    w = np.ascontiguousarray(v)
    w[3] = w[3].conjugate()                         # imaginary part only
    assert red.digest(w) != red.digest(v)


def test_digest_sees_imaginary_part_of_jax_complex_leaves():
    """jax complex leaves used to fall through an XLA convert that kept
    only the real part, so conjugation was invisible to the digest."""
    red = StateReducer()
    z = jnp.asarray(np.array([1 + 2j, 3 + 4j], np.complex64))
    assert red.digest(z) != red.digest(jnp.conj(z))
    z64 = jnp.asarray(np.array([1 + 2j, 3 + 4j]))
    assert red.digest(z64) != red.digest(jnp.conj(z64))


def test_digest_many_matches_per_object_digests():
    red = StateReducer()
    rng = np.random.default_rng(8)
    objs = {
        "a": rng.standard_normal(500).astype(np.float32),
        "b": jnp.asarray(rng.standard_normal(64), jnp.float32),
        "tree": {"x": rng.standard_normal(10), "y": [1, 2]},
        "host": "just a string",
        "wide": rng.integers(0, 2**40, 7).astype(np.int64),
    }
    singles = {n: red.digest(v) for n, v in objs.items()}
    assert red.digest_many(objs) == singles
