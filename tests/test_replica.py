"""Replica plane: follower convergence (tombstones included), zero-replay
promotion, trickle-bank dedupe, first-result-wins racing (bit-identical
commits, cancel-before-run protection), and the degenerate K=0 case."""
import numpy as np
import pytest

from repro.core import (
    EnvironmentRegistry, ExecutionEnvironment, HybridRuntime, Notebook,
    SessionScheduler, StateReducer,
)
from repro.core import telemetry as T
from repro.core import wire
from repro.core.transport import attach_peer


def _runtime(followers=("standby",), *, race=False, replicator=False,
             extra_envs=(), **kw):
    nb = Notebook("replica-demo")
    nb.add_cell("import numpy as np\n"
                "a = np.arange(4000, dtype=np.float64)\n"
                "b = np.arange(100, dtype=np.float64)", cost=0.1)
    nb.add_cell("c = float(a.sum() + b.sum())", cost=30.0)
    nb.add_cell("d = c + 1", cost=0.1)
    envs = {"local": ExecutionEnvironment("local"),
            "standby": ExecutionEnvironment("standby", speedup=10.0)}
    for name in extra_envs:
        envs[name] = ExecutionEnvironment(name, speedup=10.0)
    rt = HybridRuntime(nb, envs=envs, policy="cost", use_knowledge=False,
                       latency=0.01, bandwidth=1e6, **kw)
    rep = rt.attach_replicator(rate=1e9, top_k=2) if replicator else None
    rs = rt.attach_replicas(list(followers), race=race, rate=1e9)
    return nb, rt, rs, rep


# -- follower convergence ----------------------------------------------


def test_follower_converges_and_watermark_advances():
    nb, rt, rs, _ = _runtime()
    rt.run_cell(0)
    assert rs.commit_seq == 1 and rs.lag("standby") == 1
    shipped = rs.sync(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    assert shipped > 0
    assert rs.watermark["standby"] == rs.commit_seq == 1
    assert rs.lag() == 0
    np.testing.assert_array_equal(rt.envs["standby"].state["a"],
                                  rt.envs["local"].state["a"])
    msgs = [m for m in rt.bus.messages() if m.type == T.STATE_REPLICATED]
    assert msgs and msgs[-1].payload["watermark"] == 1
    rt.close()


def test_follower_converges_under_midstream_tombstones():
    """A name deleted on the primary after it replicated must disappear
    from the follower on the next sync — even when nothing else is dirty."""
    nb, rt, rs, _ = _runtime()
    rt.run_cell(0)
    rs.sync(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    assert "b" in rt.envs["standby"].state.ns
    rt.envs["local"].execute("del b")
    rt.envs["local"].state.mark_dirty([])
    rs.sync(rt.clock.now() + 2.0, budget_bytes=1 << 30)
    assert "b" not in rt.envs["standby"].state.ns
    assert "a" in rt.envs["standby"].state.ns
    msgs = [m for m in rt.bus.messages() if m.type == T.STATE_REPLICATED]
    assert "b" in msgs[-1].payload["deleted"]
    rt.close()


def test_budget_paces_convergence_but_always_progresses():
    nb, rt, rs, _ = _runtime()
    rt.run_cell(0)
    # tiny budget: at least one name still ships (progress guarantee),
    # but the follower does not fully converge in one wakeup
    shipped = rs.sync(rt.clock.now() + 1.0, budget_bytes=1)
    assert shipped > 0
    assert rs.lag("standby") == 1          # not converged yet
    rs.sync(rt.clock.now() + 2.0, budget_bytes=1 << 30)
    assert rs.lag("standby") == 0
    rt.close()


# -- dedupe with the trickle bank (satellite 1) -------------------------


def test_replica_claims_trickle_bank_no_double_bytes():
    """When a follower is also a trickle destination, each dirty chunk
    crosses once: the replica sync claims the banked copy manifest-only,
    and the next trickle step ships zero new bytes for those names."""
    nb, rt, rs, rep = _runtime(replicator=True)
    rt.run_cell(0)
    rep.step(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    assert "a" in rep.banked.get("standby", {})
    trickled_before = rep.trickled_bytes
    rs.sync(rt.clock.now() + 2.0, budget_bytes=1 << 30)
    # the sync claimed the bank instead of re-serializing: shared bytes
    # grew, fresh replication bytes did not
    assert rs.shared_bytes > 0
    assert rs.replicated_bytes == 0
    assert "a" not in rep.banked.get("standby", {})
    assert "a" in rt.envs["standby"].state.ns
    # and the trickle ledger carries no double bytes: a second trickle
    # step sees the synced digests as already-known and ships nothing
    rep.step(rt.clock.now() + 3.0, budget_bytes=1 << 30)
    assert rep.trickled_bytes == trickled_before
    rt.close()


def test_trickle_after_replica_sync_ships_nothing():
    """The other direction of the dedupe: names the replica set already
    applied never trickle again (the replicator's effective-known view
    includes the synced digests)."""
    nb, rt, rs, rep = _runtime(replicator=True)
    rt.run_cell(0)
    rs.sync(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    assert rs.replicated_bytes > 0
    shipped = rep.step(rt.clock.now() + 2.0, budget_bytes=1 << 30)
    assert shipped == 0
    assert "a" not in rep.banked.get("standby", {})
    rt.close()


# -- promotion ----------------------------------------------------------


def test_zero_replay_promotion_of_converged_follower():
    nb, rt, rs, _ = _runtime()
    rt.run_cell(0)
    rs.sync(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    res = rs.promote("local", rt.clock.now())
    assert res == ("standby", 0)           # converged: nothing to replay
    assert rt.current_env == "standby"
    assert rs.promotions == 1
    msgs = [m for m in rt.bus.messages() if m.type == T.SESSION_PROMOTED]
    assert msgs[-1].payload["replay"] == 0
    rt.close()


def test_promotion_applies_residual_bank_and_reports_replay():
    """An unconverged follower still promotes: the banked trickle applies
    manifest-only and the replay count covers the unconverged tail."""
    nb, rt, rs, rep = _runtime(replicator=True)
    rt.run_cell(0)
    rep.step(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    assert "a" in rep.banked.get("standby", {})
    assert "a" not in rt.envs["standby"].state.ns
    res = rs.promote("local", rt.clock.now())
    assert res is not None
    follower, replay = res
    assert follower == "standby" and replay == 1
    # the residual bank landed in the promoted namespace
    np.testing.assert_array_equal(rt.envs["standby"].state["a"],
                                  rt.envs["local"].state["a"])
    msgs = [m for m in rt.bus.messages() if m.type == T.SESSION_PROMOTED]
    assert "a" in msgs[-1].payload["residual"]
    rt.close()


def test_promote_returns_none_without_live_follower():
    nb, rt, rs, _ = _runtime()
    rt.run_cell(0)
    rt.envs["standby"].status = "failed"
    assert rs.promote("local", rt.clock.now()) is None
    rt.close()


def test_forget_resets_dead_follower_watermark():
    nb, rt, rs, _ = _runtime()
    rt.run_cell(0)
    rs.sync(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    assert rs.watermark["standby"] == 1
    rs.forget("standby")
    assert rs.watermark["standby"] == 0
    rt.close()


# -- first-result-wins racing ------------------------------------------


def _raced_runtime(race):
    """Two equal-speed cloud envs: after a history-building first pass the
    heavy cell prices identically on both, which is exactly the
    within-band disagreement the race admission looks for."""
    nb = Notebook("race-demo")
    nb.add_cell("import numpy as np\n"
                "a = np.arange(2000, dtype=np.float64)", cost=0.1)
    nb.add_cell("t = float(a.sum())", cost=30.0)
    nb.add_cell("u = t + 1", cost=0.1)
    envs = {"local": ExecutionEnvironment("local"),
            "fast-a": ExecutionEnvironment("fast-a", speedup=10.0),
            "fast-b": ExecutionEnvironment("fast-b", speedup=10.0)}
    rt = HybridRuntime(nb, envs=envs, policy="cost", use_knowledge=False,
                       latency=0.01, bandwidth=1e8)
    rs = rt.attach_replicas(["fast-a", "fast-b"], race=race, rate=1e9)
    for _pass in range(2):
        for order in range(3):
            rt.run_cell(order)
            rs.sync(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    return rt, rs


def test_race_fires_and_commits_bit_identical_result():
    solo_rt, solo_rs = _raced_runtime(race=False)
    raced_rt, raced_rs = _raced_runtime(race=True)
    assert solo_rs.races == 0
    assert raced_rs.races >= 1
    want = float(np.arange(2000, dtype=np.float64).sum())
    for rt in (solo_rt, raced_rt):
        env = next(e for e in rt.envs.values() if "t" in e.state.ns)
        assert float(env.state["t"]) == want     # bit-identical commit
        assert float(rt.envs[rt.current_env].state["u"]) == want + 1
    assert sum(raced_rs.race_wins.values()) == raced_rs.races
    assert raced_rs.race_waste_seconds >= 0.0
    raced = [m for m in raced_rt.bus.messages() if m.type == T.CELL_RACED]
    settled = [m for m in raced_rt.bus.messages()
               if m.type == T.CELL_RACE_CANCELLED]
    assert len(raced) == raced_rs.races == len(settled)
    assert settled[-1].payload["committed"] == raced[-1].payload["winner"]
    solo_rt.close()
    raced_rt.close()


def test_primary_failure_during_race_keeps_follower_state():
    """Satellite 3: the loser CANCEL fired by a mid-race primary failure
    must not clobber the (about to be promoted) follower's committed
    state, and the subsequent promotion must succeed."""
    rt, rs = _raced_runtime(race=True)
    assert rs.races >= 1
    # stage an in-flight race whose loser is the converged follower
    from repro.core.replica import RaceTicket
    rs._active_race = RaceTicket(
        race_id="test-race-inflight", order=1, winner="fast-a",
        loser="fast-b", winner_est=3.0, loser_est=3.0,
        started_at=rt.clock.now(), policy_env="fast-a")
    before = {n: rt.envs["fast-b"].state.ns[n]
              for n in ("a", "t") if n in rt.envs["fast-b"].state.ns}
    assert before                          # follower actually holds state
    waste_before = rs.race_waste_seconds
    rt.recover_from_failure("fast-a")
    assert rs._active_race is None         # race aborted...
    assert rs.race_waste_seconds == waste_before   # ...without waste
    for n, v in before.items():            # ...and nothing clobbered
        assert rt.envs["fast-b"].state.ns[n] is v
    res = rs.promote("fast-a", rt.clock.now())
    assert res is not None and res[0] == "fast-b"
    rt.close()


# -- RACE / REPLICA / PROMOTE over a live transport ---------------------


def test_race_frames_round_trip():
    f = wire.race_frame("r-1", "run", "x = 1")
    doc = wire.parse_race(f)
    assert doc == {"id": "r-1", "action": "run", "source": "x = 1"}
    with pytest.raises(wire.WireError):
        wire.race_frame("r-1", "sideways")
    session, epoch = wire.parse_promote(wire.promote_frame("s", 7))
    assert (session, epoch) == ("s", 7)
    doc = wire.parse_replica(wire.replica_frame("s", 3, deleted=("b", "a")))
    assert doc == {"session": "s", "epoch": 3, "deleted": ("a", "b")}
    # additive: the v1 frame space simply grew
    assert {wire.REPLICA, wire.PROMOTE, wire.RACE} <= wire.FRAME_TYPES


def test_race_cancel_before_run_never_executes():
    """Wire-level clobber protection: a CANCEL that beats the run means
    the run replies 'cancelled' without touching the remote namespace."""
    env = ExecutionEnvironment("remote", speedup=10.0)
    red = StateReducer(codec="zlib")
    peer = attach_peer(env, red, kind="loopback")
    peer.race_cancel("r-dead")
    peer.race("r-dead", "boom = 1")
    recv = env._server.receiver
    assert recv.races_cancelled == 1
    assert recv.races_run == 0
    assert "boom" not in env.state.ns
    # a non-cancelled race runs against a discarded overlay
    env.state.ns["x"] = 2
    nbytes = peer.race("r-live", "y = x * 2")
    assert nbytes > 0
    assert env._server.receiver.races_run == 1
    assert "y" not in env.state.ns         # overlay discarded
    peer.close()


def test_replicate_and_promote_frames_advance_remote_watermark():
    env = ExecutionEnvironment("remote", speedup=10.0)
    red = StateReducer(codec="zlib")
    peer = attach_peer(env, red, kind="loopback")
    from repro.core.state import ExecutionState
    src = ExecutionState({"a": np.arange(64, dtype=np.float32)})
    ser = red.serialize_names(src, {"a"})
    peer.replicate("sess", 5, ser)
    recv = env._server.receiver
    assert recv.replica_epoch == 5 and recv.replicas_applied == 1
    np.testing.assert_array_equal(env.state.ns["a"], src.ns["a"])
    assert peer.promote("sess", 9) == 5    # remote watermark authoritative
    assert recv.promotions == 1
    peer.close()


# -- fleet integration --------------------------------------------------


def _failover_fleet(mode):
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.3)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment("gpu-cloud", speedup=10.0), capacity=1)
    reg.register(ExecutionEnvironment("gpu-standby", speedup=10.0),
                 capacity=1)
    sched = SessionScheduler(reg)
    if mode == "replica":
        sched.enable_replicas(2)
        sched.enable_recovery("rerun")     # the fallback when no follower
    else:
        sched.enable_recovery(mode)
    sched.inject_failure("gpu-cloud", at=14.0, recover_after=10.0)
    nb = Notebook("failover")
    nb.add_cell("import numpy as np\n"
                "data = np.arange(50_000, dtype=np.float64)", cost=4.0)
    nb.add_cell("model = float((data ** 2).sum())", cost=80.0)
    nb.add_cell("model2 = model + 1", cost=80.0)
    nb.add_cell("out = model2 / 2", cost=0.3)
    sched.add_notebook(nb, policy="cost", use_knowledge=False,
                       think=[1.0] * 4)
    return sched.run()


def test_scheduler_promotes_instead_of_rerunning():
    rep = _failover_fleet("replica")
    rerun = _failover_fleet("rerun")
    s = rep.sessions[0]
    assert s.cells_run == 4
    assert rep.promotions == 1 and s.promotions == 1
    assert rep.recoveries == 1
    assert s.replicated_bytes > 0
    # promotion resumes the plan instead of replaying it from home
    assert rep.makespan < rerun.makespan
    assert rep.replica_shared_bytes >= 0


def test_scheduler_replicas_validation():
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.3)
    reg.register(ExecutionEnvironment("local"), home=True)
    sched = SessionScheduler(reg)
    with pytest.raises(ValueError):
        sched.enable_replicas(-1)
    with pytest.raises(ValueError):
        sched.enable_replicas(2, followers=["a", "a"])
    sched.enable_replicas(0)
    assert sched.replica_cfg is None       # K=0 is exactly today's behavior


def test_degenerate_no_replicas_reports_zero():
    reg = EnvironmentRegistry(default_bandwidth=1e6, default_latency=0.01)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=4)
    reg.register(ExecutionEnvironment("remote", speedup=10.0), capacity=4)
    sched = SessionScheduler(reg)
    nb = Notebook("plain")
    nb.add_cell("v = 1", cost=0.1)
    nb.add_cell("w = v + 1", cost=0.1)
    sched.add_notebook(nb, plan=[0, 1], policy="cost", use_knowledge=False)
    rep = sched.run()
    assert rep.promotions == 0 and rep.races == 0
    assert rep.replicated_bytes == 0
    s = rep.sessions[0]
    assert s.replica_lag == 0 and s.promotions == 0 and s.races == 0
