"""Background delta replication: trickle → bank → claim/cancel lifecycle,
the unified speculation-waste ledger, liveness pruning, and the degenerate
case (replication off is bit-identical to the pre-replication decisions)."""
import json
import os

import numpy as np
import pytest

from repro.core import (
    EnvironmentRegistry, ExecutionEnvironment, HybridRuntime, Notebook,
    SessionScheduler,
)
from repro.core import telemetry as T

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "fig_decisions_golden.json")


def _replicated_runtime(think_cells=None, **kw):
    nb = Notebook("rep-demo")
    nb.add_cell("import numpy as np\n"
                "a = np.arange(4000, dtype=np.float64)\n"
                "b = np.arange(100, dtype=np.float64)", cost=0.1)
    nb.add_cell("c = float(a.sum() + b.sum())", cost=30.0)
    nb.add_cell("d = c + 1", cost=0.1)
    rt = HybridRuntime(
        nb, envs={"local": ExecutionEnvironment("local"),
                  "remote": ExecutionEnvironment("remote", speedup=10.0)},
        policy="cost", use_knowledge=False, latency=0.01, bandwidth=1e6, **kw)
    rep = rt.attach_replicator(rate=1e9, top_k=2)
    return nb, rt, rep


def test_trickle_banks_then_claim_ships_manifest_only():
    """Think-time trickle lands state in the target's bank; the decision-time
    migration claims it for manifest-sized bytes instead of re-shipping."""
    nb, rt, rep = _replicated_runtime()
    rt.run_cell(0)
    # think-time gap: the replicator wakes and trickles toward the
    # predicted next cell's environment (the heavy cell 1 -> remote)
    shipped = rep.step(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    assert shipped > 0
    assert "a" in rep.banked.get("remote", {})
    banked_before = rep.banked_bytes("remote")
    assert banked_before == shipped
    rt.run_cell(1)                       # migrates local -> remote
    mig = next(m for m in rt.engine.log if m.dst == "remote" and not m.noop)
    assert set(mig.claimed) >= {"a"}     # banked names claimed, not re-sent
    assert mig.nbytes < banked_before / 10   # manifest-only residual
    assert rep.claimed_bytes > 0
    assert "remote" not in rep.banked or "a" not in rep.banked["remote"]
    assert float(rt.envs["remote"].state["c"]) == pytest.approx(
        float(np.arange(4000, dtype=np.float64).sum()
              + np.arange(100, dtype=np.float64).sum()))
    rt.close()


def test_trickle_does_not_touch_target_namespace_until_claim():
    """Banked chunks are speculative: the receiving namespace must not see
    the name before a migration claims it."""
    nb, rt, rep = _replicated_runtime()
    rt.run_cell(0)
    rep.step(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    assert "a" in rep.banked.get("remote", {})
    assert "a" not in rt.envs["remote"].state.ns
    rt.close()


def test_midtrickle_redefinition_tombstones_bank_and_charges_waste():
    """A cell that redefines a banked name invalidates the banked copy
    (CANCEL) and folds the dead bytes into the one speculation-waste
    ledger — regression for stale banks surviving redefinition."""
    nb, rt, rep = _replicated_runtime()
    rt.run_cell(0)
    rep.step(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    wasted_entry = rep.banked["remote"]["a"].nbytes
    assert wasted_entry > 0
    eng = rt.engine
    before = eng.prefetch_wasted_bytes
    rt.run_cell(0)                        # redefines a and b mid-trickle
    assert "a" not in rep.banked.get("remote", {})
    assert eng.prefetch_wasted_bytes >= before + wasted_entry
    assert rep.cancelled_names >= 1
    cancels = [m for m in rt.bus.messages()
               if m.type == T.STATE_TRICKLE_CANCELLED]
    assert cancels and "a" in cancels[-1].payload["names"]
    rt.close()


def test_superseded_trickle_charges_old_bytes_to_waste_ledger():
    """Re-trickling a name that is already banked replaces the entry and
    accounts the superseded bytes as waste."""
    nb, rt, rep = _replicated_runtime()
    rt.run_cell(0)
    rep.step(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    old = rep.banked["remote"]["a"].nbytes
    # redefine and re-trickle: invalidate() fires first (tombstone), so to
    # exercise the supersede path, mutate the bank clock directly by
    # re-banking the same names via a fresh trickle after a no-invalidate
    # change to the dirty ledger
    rt.envs["local"].execute("a = a * 2.0")
    rt.envs["local"].state.mark_dirty(["a"])
    before = rt.engine.prefetch_wasted_bytes
    rep.step(rt.clock.now() + 2.0, budget_bytes=1 << 30)
    assert rep.banked["remote"]["a"].nbytes > 0
    assert rt.engine.prefetch_wasted_bytes >= before + old
    rt.close()


def test_liveness_prunes_dead_names_from_trickle_and_return():
    """Names no remaining cell can reach are skipped by both the trickle
    and the full-state return migration."""
    nb = Notebook("rep-dead")
    nb.add_cell("import numpy as np\n"
                "big_dead = np.arange(50000, dtype=np.float64)\n"
                "keep = np.arange(100, dtype=np.float64)", cost=0.1)
    nb.add_cell("r = float(keep.sum())", cost=30.0)
    nb.add_cell("s = r + 1", cost=0.1)
    rt = HybridRuntime(
        nb, envs={"local": ExecutionEnvironment("local"),
                  "remote": ExecutionEnvironment("remote", speedup=10.0)},
        policy="cost", use_knowledge=False, latency=0.01, bandwidth=1e6)
    rep = rt.attach_replicator(rate=1e9, liveness=True)
    rt.run_cell(0)
    remaining = [nb.cells[1].source, nb.cells[2].source]
    rep.step(rt.clock.now() + 1.0, remaining_sources=remaining,
             budget_bytes=1 << 30)
    banked = rep.banked.get("remote", {})
    assert "keep" in banked and "big_dead" not in banked
    rt.run_cell(1)
    shipped = {n for m in rt.engine.log for n in m.names}
    assert "big_dead" not in shipped
    assert float(rt.envs["remote"].state["r"]) == pytest.approx(
        float(np.arange(100, dtype=np.float64).sum()))
    rt.close()


def test_replication_events_on_bus():
    nb, rt, rep = _replicated_runtime()
    rt.run_cell(0)
    rep.step(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    rt.run_cell(1)
    types = [m.type for m in rt.bus.messages()]
    assert T.STATE_TRICKLED in types
    assert T.STATE_TRICKLE_CLAIMED in types
    rt.close()


def test_recover_from_failure_forgets_banks():
    """A failed env's bank is stale by definition: recovery drops it and
    charges the bytes to the waste ledger."""
    nb, rt, rep = _replicated_runtime()
    rt.run_cell(0)
    rep.step(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    wasted_entry = rep.banked_bytes("remote")
    assert wasted_entry > 0
    before = rt.engine.prefetch_wasted_bytes
    rt.recover_from_failure("remote")
    assert rep.banked_bytes("remote") == 0
    assert rt.engine.prefetch_wasted_bytes >= before + wasted_entry
    rt.close()


# -- scheduler integration --------------------------------------------


def _fleet(replicate: bool):
    reg = EnvironmentRegistry(default_bandwidth=1e6, default_latency=0.01)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=4)
    reg.register(ExecutionEnvironment("remote", speedup=10.0), capacity=4)
    sched = SessionScheduler(reg)
    nb = Notebook("fleet-rep")
    nb.add_cell("import numpy as np\n"
                "v = np.arange(4000, dtype=np.float64)", cost=0.1)
    nb.add_cell("t = float(v.sum())", cost=30.0)
    nb.add_cell("u = t + 1", cost=0.1)
    sched.add_notebook(nb, plan=[0, 1, 2], policy="cost",
                       use_knowledge=False, think=[5.0, 5.0, 5.0])
    if replicate:
        sched.enable_replication(rate=1e9, interval=1.0)
    return sched


def test_scheduler_replication_report_fields():
    rep = _fleet(replicate=True).run()
    assert rep.trickled_bytes > 0
    assert rep.trickle_claimed_bytes > 0
    s = rep.sessions[0]
    assert s.trickled_bytes == rep.trickled_bytes
    assert s.trickle_claimed_bytes == rep.trickle_claimed_bytes
    assert rep.wasted_speculation_bytes >= 0


def test_scheduler_without_replication_reports_zero_trickle():
    rep = _fleet(replicate=False).run()
    assert rep.trickled_bytes == 0
    assert rep.trickle_claimed_bytes == 0


# -- degenerate case: replication off is the identity ------------------


def test_fig_decisions_bit_identical_with_replication_off():
    """With no replicator attached (the default), the fig5/fig11 decision
    sweeps must reproduce the committed goldens *bit-identically* — the
    replication hook must not perturb a single decision or byte count."""
    from benchmarks import fig5_fig6_policy_speedups, fig11_knowledge_policy
    with open(GOLDEN) as f:
        golden = json.load(f)
    fresh5 = [[n, v, d] for n, v, d in fig5_fig6_policy_speedups.run(smoke=True)]
    fresh11 = [[n, v, d] for n, v, d in fig11_knowledge_policy.run(smoke=True)]
    assert fresh5 == golden["fig5_fig6"]
    assert fresh11 == golden["fig11"]
