"""Sharded-execution tests in a subprocess with 8 host devices.

(The main test process must keep the default single device — see conftest.)
These actually EXECUTE sharded programs, unlike the dry-run which only
compiles them.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_runs():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, TrainConfig
        from repro.configs.base import ShapeConfig
        from repro.distributed.context import DistContext
        from repro.distributed.steps import build_train_step
        from repro.models import LM
        from repro.optim import init_opt_state

        cfg = get_config('yi-6b', reduced=True)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        ctx = DistContext.create(cfg, mesh)
        shape = ShapeConfig('t', 'train', 32, 4)
        lm = LM(cfg, max_seq=33)
        tc = TrainConfig(microbatches=2, remat='full')
        with mesh:
            jf, (ap, ao, ab) = build_train_step(lm, tc, ctx, shape)
            params = lm.init(jax.random.PRNGKey(0))
            opt = init_opt_state(params)
            batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1),
                                                  (4, 33), 0, cfg.vocab_size)}
            p2, o2, m = jf(params, opt, batch)
            print('LOSS', float(m['loss']), int(o2.step))
    """)
    loss = float(out.split("LOSS ")[1].split()[0])
    assert 0.0 < loss < 20.0


@pytest.mark.slow
def test_sp_decode_attention_matches_plain():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed.context import DistContext
        from repro.distributed.decode_attn import sp_decode_attention
        from repro.models.attention import cache_write_plain, decode_attention_plain

        cfg = get_config('yi-6b', reduced=True)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        ctx = DistContext.create(cfg, mesh)
        B, KV, S, hd, H = 4, 2, 64, 16, 4
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
        kc = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
        vc = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
        nk = jax.random.normal(ks[3], (B, 1, KV, hd), jnp.float32)
        nv = jax.random.normal(ks[4], (B, 1, KV, hd), jnp.float32)
        pos = jnp.array([5, 17, 33, 63])

        with mesh:
            o_sp, k_sp, v_sp = jax.jit(
                lambda *a: sp_decode_attention(ctx, *a))(q, kc, vc, nk, nv, pos)
        k_pl, v_pl = cache_write_plain(kc, vc, nk, nv, pos)
        o_pl = decode_attention_plain(q, k_pl, v_pl, pos)
        np.testing.assert_allclose(np.asarray(o_sp), np.asarray(o_pl),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(k_sp), np.asarray(k_pl), atol=0)
        print('SP_MATCH')
    """)
    assert "SP_MATCH" in out


@pytest.mark.slow
def test_shardmap_moe_matches_dense_oracle():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed.context import DistContext
        from repro.models.moe import moe_ffn, moe_spec
        from repro.models.layers import init_params

        cfg = dataclasses.replace(get_config('qwen3-moe-235b-a22b', reduced=True),
                                  capacity_factor=16.0)  # no drops => exact
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        ctx = DistContext.create(cfg, mesh)
        ctx.extra['moe_impl'] = 'shardmap'
        p = init_params(moe_spec(cfg), jax.random.PRNGKey(0), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32)
        y_ref, _ = moe_ffn(p, x, cfg, None)
        with mesh:
            y_sm, _ = jax.jit(lambda p, x: moe_ffn(p, x, cfg, ctx))(p, x)
            g = jax.jit(jax.grad(lambda p, x: jnp.sum(
                moe_ffn(p, x, cfg, ctx)[0] ** 2)))(p, x)
        np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        assert bool(jnp.all(jnp.isfinite(g['w_gate'])))
        print('SHARDMAP_MOE_OK')
    """)
    assert "SHARDMAP_MOE_OK" in out


@pytest.mark.slow
def test_multipod_mesh_dev_scale():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.distributed.context import DistContext
        from repro.distributed.steps import build_prefill_step, build_decode_step
        from repro.models import LM

        cfg = get_config('recurrentgemma-9b', reduced=True)
        mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        ctx = DistContext.create(cfg, mesh)
        lm = LM(cfg, max_seq=64)
        shape = ShapeConfig('p', 'prefill', 64, 4)
        with mesh:
            jf, args = build_prefill_step(lm, ctx, shape)
            jf.lower(*args).compile()
        shape_d = ShapeConfig('d', 'decode', 64, 8)
        with mesh:
            jd, argsd = build_decode_step(lm, ctx, shape_d)
            jd.lower(*argsd).compile()
        print('MULTIPOD_OK')
    """)
    assert "MULTIPOD_OK" in out
