"""Policy simulator (paper §III, Figs. 4-10)."""
import numpy as np
import pytest

from repro.core import (
    cell_frequency, policy_grid, simulate, synthetic_loops_trace,
    tf_guide_trace,
)


@pytest.fixture(scope="module")
def traces():
    return synthetic_loops_trace(), tf_guide_trace()


def test_traces_deterministic(traces):
    t1 = synthetic_loops_trace()
    t2 = synthetic_loops_trace()
    assert t1.order == t2.order and t1.costs == t2.costs


def test_traces_have_cycles(traces):
    syn, tf = traces
    # Fig. 4: both traces revisit earlier cells (execution cycles)
    assert any(b < a for a, b in zip(syn.order, syn.order[1:]))
    assert any(b < a for a, b in zip(tf.order, tf.order[1:]))


def test_tf_guide_two_time_groups(traces):
    _, tf = traces
    costs = np.array(list(tf.costs.values()))
    assert (costs > 10).sum() >= 2 and (costs < 1).sum() >= 8  # Fig. 7


def test_block_beats_single_everywhere(traces):
    # paper §III-C: "block-cell migration outperforms single-cell for all
    # combinations of full remote speedups and migration times"
    for tr in traces:
        for mt in (0.1, 1.0, 5.0):
            for rs in (10, 50, 150):
                local = simulate(tr, "local", migration_time=mt, remote_speedup=rs)
                sng = simulate(tr, "single", migration_time=mt, remote_speedup=rs)
                blk = simulate(tr, "block", migration_time=mt, remote_speedup=rs)
                assert blk.total_seconds <= sng.total_seconds * 1.001, (
                    tr.name, mt, rs)
                assert sng.total_seconds <= local.total_seconds * 1.001


def test_block_fewer_migrations(traces):
    syn, _ = traces
    sng = simulate(syn, "single", migration_time=1.0, remote_speedup=50)
    blk = simulate(syn, "block", migration_time=1.0, remote_speedup=50)
    assert blk.migrations < sng.migrations


def test_speedup_shape_matches_paper(traces):
    # max speedup at min migration time + max remote speedup (Fig. 5)
    syn, _ = traces
    grid = policy_grid(syn, migration_times=[0.1, 2.0, 10.0],
                       remote_speedups=[5, 50, 200], policies=("block",))
    sp = np.array(grid["speedup"]["block"])
    assert sp[0, -1] == sp.max()          # corner: low mig, high speedup
    assert sp[-1, 0] == sp.min()


def test_migration_cap_high_cost(traces):
    syn, _ = traces
    r = simulate(syn, "block", migration_time=1e9, remote_speedup=200)
    assert r.migrations == 0              # never worth it
    loc = simulate(syn, "local", migration_time=0, remote_speedup=1)
    assert r.total_seconds == pytest.approx(loc.total_seconds)


def test_cell_frequency(traces):
    syn, _ = traces
    freq = cell_frequency(syn)
    assert abs(sum(v["freq"] for v in freq.values()) - 1.0) < 1e-9
    assert all(v["count"] >= 1 for v in freq.values())
