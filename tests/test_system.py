"""End-to-end system behaviour: the paper's migration stack managing a real
JAX training workload, with checkpoint/restart riding the same engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import TrainConfig, get_config
from repro.core import ExecutionEnvironment, HybridRuntime, Notebook
from repro.data import TokenPipeline
from repro.configs.base import ShapeConfig
from repro.models import LM
from repro.optim import adamw_update, init_opt_state


def test_hybrid_runtime_manages_jax_training(tmp_path):
    """A notebook whose heavy cell trains a (reduced) assigned-arch model:
    the runtime learns to run it remotely, state migrates correctly (loss
    continues to drop on migrated state), decisions are explained, and the
    delta checkpoint restores bit-exact."""
    nb = Notebook("train-notebook")
    nb.add_cell("""
import jax, jax.numpy as jnp
from repro.configs import TrainConfig, get_config
from repro.models import LM
from repro.optim import adamw_update, init_opt_state
cfg = get_config('demo-100m', reduced=True)
lm = LM(cfg, max_seq=33)
params = lm.init(jax.random.PRNGKey(0))
opt = init_opt_state(params)
tc = TrainConfig(total_steps=20, warmup_steps=2)
losses = []
""", cost=0.5)
    nb.add_cell("""
import numpy as np
toks = jnp.asarray(np.random.default_rng(0).integers(
    0, cfg.vocab_size, (4, 33), dtype=np.int32))
""", cost=0.2)
    train = nb.add_cell("""
for _ in range(3):
    (loss, _), grads = jax.value_and_grad(lm.loss, has_aux=True)(
        params, {'tokens': toks})
    opt, params, _ = adamw_update(tc, opt, grads, params)
    losses.append(float(loss))
""", cost=25.0)
    nb.add_cell("final_loss = losses[-1]", cost=0.1)

    rt = HybridRuntime(
        nb, envs={"local": ExecutionEnvironment("local"),
                  "remote": ExecutionEnvironment("remote", speedup=10.0)},
        policy="block", use_knowledge=False, bandwidth=1e9, latency=0.5)
    for _ in range(3):
        for i in range(len(nb.cells)):
            rt.run_cell(i)
    rt.close()

    # policy beat local-only and the heavy cell ran remotely at least once
    local_only = 3 * sum(c.cost for c in nb.cells)
    assert rt.clock.now() < local_only
    assert "losses" in rt.envs["remote"].state.ns
    # training progressed across migrations (cell 0 re-inits each session,
    # so the last session holds 3 optimizer steps — and they must have run
    # on correctly-migrated state: loss monotone progress)
    losses = rt.envs["local"].state.get("losses") or rt.envs["remote"].state["losses"]
    assert len(losses) == 3
    assert losses[-1] < losses[0]
    assert any("performance" in a for a in train.annotations)  # explainability

    # checkpoint the migrated training state; restore must be bit-exact
    env = ("local" if "params" in rt.envs["local"].state.ns else "remote")
    params = rt.envs[env].state["params"]
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"params": params})
    out, step = ck.restore({"params": params})
    flat_a = jax.tree_util.tree_leaves(out["params"])
    flat_b = jax.tree_util.tree_leaves(params)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_training_reproducible_after_restart(tmp_path):
    """Kill-and-restart equivalence: steps 0..5 straight vs checkpoint at 3 +
    resume gives identical parameters (data pipeline is step-keyed)."""
    cfg = get_config("demo-100m", reduced=True)
    lm = LM(cfg, max_seq=33)
    tc = TrainConfig(total_steps=10, warmup_steps=2)
    pipe = TokenPipeline(cfg, ShapeConfig("t", "train", 32, 4), seed=1)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(lm.loss, has_aux=True)(params, batch)
        opt, params, _ = adamw_update(tc, opt, grads, params)
        return params, opt

    def run(params, opt, lo, hi):
        for s in range(lo, hi):
            b = {k: jnp.asarray(v) for k, v in pipe.train_batch(s).items()}
            params, opt = step(params, opt, b)
        return params, opt

    p0 = lm.init(jax.random.PRNGKey(0))
    o0 = init_opt_state(p0)

    # straight run
    p_straight, _ = run(p0, o0, 0, 6)

    # run to 3, checkpoint, restart from disk, continue to 6
    p3, o3 = run(p0, o0, 0, 3)
    ck = Checkpointer(str(tmp_path))
    ck.save(3, {"params": p3, "opt": o3._asdict()})
    restored, s = ck.restore({"params": p3, "opt": o3._asdict()})
    assert s == 3
    from repro.optim.optimizer import OptState
    p_resumed, _ = run(restored["params"], OptState(**restored["opt"]), 3, 6)

    for a, b in zip(jax.tree_util.tree_leaves(p_straight),
                    jax.tree_util.tree_leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
