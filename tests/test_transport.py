"""Transport-plane tests: loopback and socket transports carrying real
migration traffic, token-bucket shaping, prefetch cancellation as frames,
and the subprocess end-to-end (namespace out, cell executed remotely,
results round-tripped home)."""
import numpy as np
import pytest

from repro.core.fabric import EnvironmentRegistry, ExecutionEnvironment
from repro.core.migration import MigrationEngine, PipelinedMigrationEngine
from repro.core.reducer import StateReducer
from repro.core.transport import (
    DigestMirrorStore, LoopbackTransport, SubprocessEnv, TokenBucket,
    attach_peer,
)
from repro.core.wire import WireError


def _rig(kind, *, pipeline=False, shaper=None):
    reg = EnvironmentRegistry.two_env()
    red = StateReducer(codec="zlib")
    cls = PipelinedMigrationEngine if pipeline else MigrationEngine
    eng = cls(red, registry=reg)
    peer = attach_peer(reg["remote"], red, kind=kind, shaper=shaper)
    return reg, red, eng, peer


@pytest.mark.parametrize("kind", ["loopback", "socket"])
def test_push_exec_pull_over_transport(kind):
    reg, red, eng, peer = _rig(kind)
    local, remote = reg["local"], reg["remote"]
    local.state.ns["x"] = np.arange(1000, dtype=np.float32)
    local.state.ns["msg"] = "hi"
    res = eng.migrate(local, remote, "y = x.sum() + len(msg)")
    assert res.transport == kind
    assert res.wire_frames >= 3          # manifest + >=1 chunk + end
    assert set(res.names) == {"msg", "x"}
    np.testing.assert_array_equal(remote.state.ns["x"], local.state.ns["x"])

    remote.execute("y = x.sum() + len(msg)")
    back = eng.migrate(remote, local, names={"y"})
    assert back.names == ("y",)
    assert local.state.ns["y"] == pytest.approx(float(np.arange(1000).sum()) + 2)
    peer.close()


def test_delta_and_tombstones_cross_the_wire():
    reg, red, eng, peer = _rig("socket")
    local, remote = reg["local"], reg["remote"]
    local.state.ns["x"] = np.arange(1000, dtype=np.float32)
    first = eng.migrate(local, remote, "z = x * 2")
    assert not first.noop and first.nbytes > 0
    # unchanged: empty delta is a no-op even through a real socket
    again = eng.migrate(local, remote, "z = x * 2")
    assert again.noop and again.nbytes == 0
    # deletion propagates as a TOMBSTONE frame
    del local.state.ns["x"]
    gone = eng.migrate(local, remote, None)
    assert "x" in gone.deleted
    assert "x" not in remote.state.ns
    peer.close()


def test_chunk_level_dedup_over_socket():
    reg = EnvironmentRegistry.two_env()
    red = StateReducer(codec="none", chunk_bytes=4096)
    eng = MigrationEngine(red, registry=reg)
    local, remote = reg["local"], reg["remote"]
    peer = attach_peer(remote, red, kind="socket")
    local.state.ns["big"] = np.arange(64_000, dtype=np.float32)  # ~62 chunks
    full = eng.migrate(local, remote, "s = big.sum()")
    # mutate one element: only the touched chunk re-crosses the wire
    local.state.ns["big"][7] = 1.0
    eng.invalidate("local", ["big"])
    delta = eng.migrate(local, remote, "s = big.sum()")
    assert delta.nbytes < full.nbytes / 10
    assert delta.wire_frames < full.wire_frames
    np.testing.assert_array_equal(remote.state.ns["big"], local.state.ns["big"])
    peer.close()


def test_prefetch_claim_and_cancel_send_real_frames():
    reg, red, eng, peer = _rig("socket", pipeline=True)
    local, remote = reg["local"], reg["remote"]
    local.state.ns["x"] = np.arange(2000, dtype=np.float32)
    p = eng.begin_prefetch(local, remote, "y = x + 1", now=0.0)
    assert p is not None and p.peer is not None
    # speculative stream banked chunks remotely but did NOT touch the ns
    assert "x" not in remote.state.ns
    # the claim is manifest-only (chunks already banked) and applies the ns
    res = eng.migrate(local, remote, "y = x + 1", now=p.ready_at + 1.0)
    assert "x" in res.prefetched
    # the claim's manifest-only stream is real traffic and is accounted
    assert res.wire_frames >= 2 and res.transport == "socket"
    np.testing.assert_array_equal(remote.state.ns["x"], local.state.ns["x"])
    # a superseded speculation is cancelled with a CANCEL frame
    local.state.ns["q"] = np.ones(100)
    eng.begin_prefetch(local, remote, "w = q * 2", now=10.0)
    eng.cancel_prefetch("remote", now=20.0)
    assert eng.prefetch_cancelled == 1
    # the connection stays healthy after the cancel
    ok = eng.migrate(local, remote, "w = q * 2")
    assert "q" in ok.names or ok.noop
    peer.close()


def test_module_alias_reaches_remote_even_on_empty_state_delta():
    """Regression: aliases ride the manifest, so a cell that needs only a
    module (state already synced) must still stream an alias-only
    manifest — parity with the loopback path's unconditional re-import."""
    import math
    reg, red, eng, peer = _rig("socket")
    local, remote = reg["local"], reg["remote"]
    local.state.ns["x"] = 1
    local.state.ns["math"] = math
    eng.migrate(local, remote, "x")          # syncs x; math not needed yet
    res = eng.migrate(local, remote, "y = math.sqrt(x)")  # empty state delta
    assert res.noop and res.wire_frames >= 2  # alias-only manifest streamed
    remote.execute("y = math.sqrt(x)")       # would NameError before the fix
    assert remote.state.ns["y"] == 1.0
    peer.close()


def _poison_unpickle():
    raise ValueError("poisoned unpickle")


class _Poison:
    """Pickles fine; unpickling raises — a receiver-side apply failure."""

    def __reduce__(self):
        return (_poison_unpickle, ())


def test_receiver_apply_failure_reports_promptly_and_keeps_serving():
    """Regression: a non-wire receiver exception (failed deserialize) must
    come back as an ERROR frame — a prompt WireError at the sender, not a
    60 s timeout — and the receiver keeps serving afterwards."""
    import time
    reg, red, eng, peer = _rig("socket")
    local, remote = reg["local"], reg["remote"]
    local.state.ns["p"] = _Poison()
    t0 = time.perf_counter()
    with pytest.raises(WireError, match="poisoned unpickle"):
        eng.migrate(local, remote, names={"p"})
    assert time.perf_counter() - t0 < 10.0      # not the recv timeout
    # the receiver recovered: a healthy migration still lands
    del local.state.ns["p"]
    eng.invalidate("local", ["p"])
    local.state.ns["ok"] = np.arange(10)
    res = eng.migrate(local, remote, names={"ok"})
    assert "ok" in res.names
    np.testing.assert_array_equal(remote.state.ns["ok"], np.arange(10))
    peer.close()


def test_serialization_failure_travels_as_error_frame():
    reg, red, eng, peer = _rig("socket")
    local, remote = reg["local"], reg["remote"]
    remote.state.ns["sock"] = __import__("socket").socket()  # unpicklable
    from repro.core.reducer import SerializationFailure
    with pytest.raises(SerializationFailure):
        eng.migrate(remote, local, names={"sock"}, strict=True)
    # non-strict pull skips it cleanly instead
    res = eng.migrate(remote, local, names={"sock"}, strict=False)
    assert res.names == ()
    peer.close()


def test_token_bucket_math_is_deterministic():
    t = [0.0]
    bucket = TokenBucket(1000.0, burst=500, latency=0.25, clock=lambda: t[0])
    # first 500 bytes ride the burst: latency only
    assert bucket.delay(500) == pytest.approx(0.25)
    # the next 1000 must wait for refill at 1000 B/s
    assert bucket.delay(1000) == pytest.approx(1.25)
    # time passing refills the bucket
    t[0] = 10.0
    assert bucket.delay(100) == pytest.approx(0.25)


def test_shaped_socket_transfer_is_slower_but_identical():
    _, _, eng_fast, peer_fast = _rig("socket")
    shaper = TokenBucket(200_000.0, burst=2048, latency=0.0)
    reg, red, eng, peer = _rig("socket", shaper=shaper)
    local, remote = reg["local"], reg["remote"]
    payload = np.arange(30_000, dtype=np.float32)
    local.state.ns["x"] = payload
    res = eng.migrate(local, remote, "y = x.sum()")
    np.testing.assert_array_equal(remote.state.ns["x"], payload)
    # ~120 KB compressed at 200 KB/s floor => measurable wall seconds
    assert res.wall_seconds > 0.05
    peer.close()
    peer_fast.close()


def test_digest_mirror_store_tracks_without_bytes():
    m = DigestMirrorStore()
    m.put_many({1: b"a", 2: b"b"})
    assert m.has(1) and m.has(2) and not m.has(3)
    assert len(m) == 2 and m.nbytes == 0
    with pytest.raises(KeyError):
        m.get(1)


def test_loopback_transport_is_zero_copy():
    a, b = LoopbackTransport.pair()
    from repro.core.wire import Frame, END
    f = Frame(END, b"payload-bytes")
    a.send(f)
    got = b.recv(timeout=1.0)
    assert got is f                      # the very same object, never encoded
    assert a.bytes_sent == f.wire_size
    a.close()
    with pytest.raises(WireError):
        a.send(f)


def test_scheduler_marks_env_transport():
    """The fleet plane can declare an env's migration traffic socket-bound:
    the mark audit-logs on the physical registry, mirrors into session
    clones (existing and future), and lands in the schedule report."""
    from repro.core.notebook import Notebook
    from repro.core.scheduler import SessionScheduler

    reg = EnvironmentRegistry.two_env()
    sched = SessionScheduler(reg)
    nb = Notebook("t")
    nb.add_cell("a = 1", cost=0.1)
    nb.add_cell("b = a + 1", cost=50.0)
    rt_before = sched.add_notebook(nb, policy="cost", use_knowledge=False)
    sched.set_transport("remote", "socket", now=3.0)
    nb2 = Notebook("t2")
    nb2.add_cell("c = 2", cost=0.1)
    rt_after = sched.add_notebook(nb2, policy="cost", use_knowledge=False)
    assert rt_before.registry["remote"].transport == "socket"
    assert rt_after.registry["remote"].transport == "socket"
    assert (3.0, "remote", "transport:loopback", "transport:socket") \
        in reg.lifecycle_log
    with pytest.raises(ValueError):
        sched.set_transport("remote", "carrier-pigeon")
    rep = sched.run()
    assert rep.env_transports == {"local": "loopback", "remote": "socket"}


def test_subprocess_env_end_to_end():
    """The acceptance path: migrate a namespace into a child Python
    process over real TCP, execute a cell there, round-trip the result."""
    reg = EnvironmentRegistry()
    reg.register(ExecutionEnvironment("local"), home=True)
    sub = SubprocessEnv("worker", speedup=2.0)
    try:
        reg.register(sub)
        red = StateReducer(codec="zlib")
        eng = MigrationEngine(red, registry=reg)
        local = reg["local"]
        local.state.ns["x"] = np.arange(64, dtype=np.float64)
        local.state.ns["np"] = np
        res = eng.migrate(local, sub, "y = np.square(x).sum()")
        assert res.transport == "subprocess" and res.wire_frames >= 3
        # the parent holds no copy of the remote namespace — only a mirror
        assert "x" not in sub.state.ns and len(sub.chunk_store) > 0
        sub.execute("y = np.square(x).sum()")
        back = eng.migrate(sub, local, None)
        assert "y" in back.names
        assert local.state.ns["y"] == pytest.approx(
            float(np.square(np.arange(64)).sum()))
        # remote errors surface, they don't wedge the session
        with pytest.raises(RuntimeError):
            sub.execute("raise ValueError('boom')")
        sub.execute("ok = 1")            # still serving
    finally:
        sub.close()
    assert sub.proc.returncode == 0


def test_two_lane_bucket_interactive_never_pays_trickle_deficit():
    """The low (trickle) lane waits out its own deficit and never leaves
    the bucket negative, so an interactive frame arriving right behind a
    trickle burst is delayed by at most its OWN serialization time."""
    t = [0.0]
    bucket = TokenBucket(1000.0, burst=1000, latency=0.0, clock=lambda: t[0])
    # trickle drains the burst and asks for 5x more: it pays the whole
    # 4 s deficit itself and leaves the bucket at exactly zero
    assert bucket.delay(5000, low_priority=True) == pytest.approx(4.0)
    # interactive frame right behind: delayed by only its own bytes
    nbytes = 800
    w = bucket.delay(nbytes)
    assert w == pytest.approx(nbytes / 1000.0)
    # sustained trickle pressure cannot push the bound any higher
    bucket.delay(10_000, low_priority=True)
    w2 = bucket.delay(nbytes)
    assert w2 <= nbytes / 1000.0 + 1e-9
    # but trickle frames are delayed, never dropped: each call returns a
    # finite wait that clears its deficit
    assert bucket.delay(100, low_priority=True) < float("inf")


def test_shaped_socket_trickle_yields_to_interactive_frames():
    """End-to-end on a shaped socket: a low-priority trickle stream eats
    its own shaping delay; the interactive stream that follows is not
    stuck behind the trickle's deficit."""
    rate = 1e6
    shaper = TokenBucket(rate, burst=2048, latency=0.0)
    reg, red, eng, peer = _rig("socket", shaper=shaper)
    local, remote = reg["local"], reg["remote"]
    local.state.ns["big"] = np.random.default_rng(0).standard_normal(20_000)
    ser = red.serialize_names(local.state, ["big"])
    t_stats = peer.send_state(ser, trickle=True, low_priority=True)
    assert t_stats.wire_bytes > 50_000
    # trickle paid its own shaping wait...
    assert t_stats.wall_seconds >= t_stats.wire_bytes / rate * 0.5
    # ...and banked without touching the namespace
    assert "big" not in remote.state.ns
    # interactive stream right behind the trickle burst: its wall time is
    # bounded by its own (small) bytes, not the trickle's deficit
    local.state.ns["note"] = "ping"
    i_ser = red.serialize_names(local.state, ["note"])
    i_stats = peer.send_state(i_ser)
    assert "note" in remote.state.ns
    assert i_stats.wall_seconds < t_stats.wire_bytes / rate / 2
    peer.close()
