"""Wire-format tests: golden vectors, framing invariants, and the
corruption property — a damaged stream must always be a clean WireError,
never a crash or a silently wrong decode."""
import os

import numpy as np
import pytest

from repro.core import wire
from repro.core.chunkstore import MemoryChunkStore, digest_bytes, encode_chunk
from repro.core.reducer import SerializedName, SerializedState, StateReducer
from repro.core.state import ExecutionState
from repro.core.wire import Frame, FrameDecoder, WireError

from tests._hyp_compat import given, settings, st

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "wire_v1_golden.bin")

# the canonical v1 HELLO (codec=none): any change to the framing or the
# session header is a wire-format break and must bump wire.VERSION
GOLDEN_HELLO_HEX = "0800000001525749520100000005833bd2"


def _golden_ser():
    """The SerializedState the golden stream was generated from."""
    raw = bytes(range(64)) * 4
    d = digest_bytes(raw)
    ser = SerializedState(codec="none", blobs={
        "w": SerializedName(pickle_bytes=b"\x80\x05PIN", arrays=[
            {"shape": (16, 16), "dtype": "float32", "quant": False,
             "chunks": [d], "clens": [len(raw)]}]),
        "tag": SerializedName(pickle_bytes=b"\x80\x05TAG", arrays=[]),
    })
    ser.chunks = {d: encode_chunk(raw, "none")}
    ser.digests = {"w": 0x1122334455667788, "tag": 42}
    return ser, d


def test_golden_stream_decodes_and_reencodes_byte_identical():
    with open(GOLDEN, "rb") as f:
        data = f.read()
    frames = wire.decode_frames(data)
    assert [f.ftype for f in frames] == [
        wire.HELLO, wire.MANIFEST, wire.ACK, wire.CHUNK, wire.TOMBSTONE,
        wire.END, wire.ACK]
    # decode -> re-encode must reproduce the stream byte for byte
    assert b"".join(f.encoded() for f in frames) == data
    # and the first frame is pinned down to its hex
    assert frames[0].encoded().hex() == GOLDEN_HELLO_HEX
    hello = wire.parse_hello(frames[0])
    assert hello["version"] == wire.VERSION
    assert hello["codec"] == "none"


def test_golden_manifest_roundtrips_through_the_codec():
    with open(GOLDEN, "rb") as f:
        frames = wire.decode_frames(f.read())
    ser, deleted, modules, spec, trickle = wire.parse_manifest(frames[1])
    want, d = _golden_ser()
    assert deleted == ("gone",)
    assert modules == ("np=numpy",)
    assert not spec
    assert ser.digests == want.digests
    assert ser.blobs["w"].arrays[0]["chunks"] == [d]
    # semantic re-encode is byte-identical (canonical JSON)
    again = wire.manifest_frame(ser, deleted=deleted, modules=modules)
    assert again.payload == frames[1].payload
    # the chunk frame carries the store encoding verbatim
    digest, encoded = wire.parse_chunk(frames[3])
    assert digest == d
    assert encoded == want.chunks[d]


def test_real_serialized_state_survives_the_wire():
    red = StateReducer(codec="zlib", chunk_bytes=256)
    state = ExecutionState({"a": np.arange(512, dtype=np.float32),
                            "b": {"k": [1, 2, 3]}})
    ser = red.serialize_names(state, {"a", "b"})
    frames = [wire.manifest_frame(ser)]
    frames += list(wire.state_stream_frames(ser, sorted(ser.chunks)))
    stream = b"".join(f.encoded() for f in frames)

    got = wire.decode_frames(stream)
    ser2, _deleted, _modules, _spec, _trickle = wire.parse_manifest(got[0])
    store = MemoryChunkStore()
    count, _ = store.ingest_frames(
        f for f in got if f.ftype == wire.CHUNK)
    assert count == len(ser.chunks)
    objs = red.deserialize(ser2, chunk_store=store)
    np.testing.assert_array_equal(objs["a"], state.ns["a"])
    assert objs["b"] == {"k": [1, 2, 3]}


def test_incremental_decoder_handles_byte_at_a_time_feeding():
    frames = [wire.hello_frame(), Frame(wire.END),
              wire.json_frame(wire.ACK, {"need": []})]
    data = b"".join(f.encoded() for f in frames)
    dec = FrameDecoder()
    out = []
    for i in range(len(data)):
        dec.feed(data[i:i + 1])
        out.extend(dec.frames())
    assert out == frames
    assert dec.pending_bytes == 0


def test_unknown_frame_type_and_oversized_length_rejected():
    with pytest.raises(WireError):
        wire.decode_frames(wire.encode_frame(99, b"?"))
    bad = bytearray(wire.encode_frame(wire.END, b""))
    bad[0:4] = (wire.MAX_PAYLOAD + 1).to_bytes(4, "little")
    with pytest.raises(WireError):
        wire.decode_frames(bytes(bad))


def test_truncation_is_a_clean_error_not_a_partial_apply():
    with open(GOLDEN, "rb") as f:
        data = f.read()
    for cut in (1, 9, len(data) // 2, len(data) - 1):
        with pytest.raises(WireError):
            wire.decode_frames(data[:cut])


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 255))
def test_bitflip_anywhere_is_rejected_or_decodes_identically(pos, flip):
    """Property: flipping any byte either fails as WireError or — when the
    flip misses every frame (flip == 0) — decodes identically.  It must
    never produce a *different* successfully-decoded stream: CRC coverage
    of type+payload and the length prefix bound makes silent corruption
    impossible at the framing layer."""
    with open(GOLDEN, "rb") as f:
        data = bytearray(f.read())
    good = wire.decode_frames(bytes(data))
    pos %= len(data)
    data[pos] ^= flip
    try:
        got = wire.decode_frames(bytes(data))
    except WireError:
        return
    assert got == good          # only a no-op flip may decode


def test_manifest_corruption_rejected_by_parser():
    ser, _ = _golden_ser()
    frame = wire.manifest_frame(ser)
    # valid frame, garbage payload: parser must raise WireError, not crash
    broken = Frame(wire.MANIFEST, frame.payload.replace(b'"blobs"', b'"blogs"'))
    with pytest.raises(WireError):
        wire.parse_manifest(broken)
    not_json = Frame(wire.MANIFEST, b"\xff\xfe{")
    with pytest.raises(WireError):
        wire.parse_manifest(not_json)


def test_chunk_ingest_rejects_unknown_codec_tag():
    store = MemoryChunkStore()
    with pytest.raises(WireError):
        store.ingest_frame(wire.chunk_frame(7, b"\x7fgarbage"))
    # a valid chunk frame lands verbatim
    enc = encode_chunk(b"payload", "none")
    d = digest_bytes(b"payload")
    assert store.ingest_frame(wire.chunk_frame(d, enc)) == d
    assert store.get(d) == enc


def test_hello_rejects_wrong_magic_and_version():
    f = wire.hello_frame()
    with pytest.raises(WireError):
        wire.parse_hello(Frame(wire.HELLO, b"XXXX" + f.payload[4:]))
    bad_ver = bytearray(f.payload)
    bad_ver[4] = 0xEE
    with pytest.raises(WireError):
        wire.parse_hello(Frame(wire.HELLO, bytes(bad_ver)))
    with pytest.raises(WireError):
        wire.parse_hello(Frame(wire.END))


def test_large_chunk_payload_is_a_view_into_the_fed_buffer():
    """Satellite of the zero-copy plane: a CHUNK that arrives within one
    feed() must decode to a payload that *aliases* the fed buffer — any
    copy here is a regression the benchmark would only show as noise."""
    body = b"\x00" + os.urandom(4 << 20)          # codec tag + 4 MiB chunk
    frame = wire.chunk_frame(123456789, body)
    buf = frame.encoded()
    dec = FrameDecoder()
    dec.feed(buf)
    (f,) = tuple(dec.frames())
    assert isinstance(f.payload, memoryview)
    assert f.payload.obj is buf                   # zero-copy, same object
    d, enc = wire.parse_chunk(f)
    assert d == 123456789
    assert isinstance(enc, memoryview) and enc.obj is buf
    assert bytes(enc) == body


def test_scatter_gather_segments_equal_legacy_encoding():
    body = b"\x02" + os.urandom(70_000)
    f = wire.chunk_frame(7, body)
    import struct as _s
    legacy = wire.encode_frame(wire.CHUNK, _s.pack("<Q", 7) + body)
    assert b"".join(bytes(s) for s in f.segments()) == legacy
    # and the segments really are the caller's buffers, not copies
    assert any(s is body for s in f.segments())
